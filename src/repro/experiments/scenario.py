"""Declarative scenario specs for the experiment runner.

A :class:`Scenario` is plain data (picklable, JSON-serialisable) describing
a workload: which functions are deployed (the mix), how requests arrive
(the arrival process), for how long, and against which backends.  The
:mod:`repro.experiments.runner` interprets the spec; nothing here touches
the simulator, so scenario definitions stay cheap to build and ship to
parallel worker processes.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.autoscaler import (LeadTimePolicy, QueueDepthPolicy,
                                   ScalePolicy)
from repro.core.latency import AES_600B_WORK_US
from repro.core.workload import (ArrivalProcess, BurstyArrivals, ChainEdge,
                                 DiurnalArrivals, FusionPlan, LoadSpec,
                                 PoissonArrivals, TraceReplay)

# Default matrix: the paper's pair.  Scenarios can widen this to any set
# of registered backend names (see repro.core.backends), and the runner
# computes paper-claim deltas from ``claims_pair`` regardless of how many
# other backends ride along.
DEFAULT_BACKENDS = ("containerd", "junctiond")
DEFAULT_CLAIMS_PAIR = ("containerd", "junctiond")


@dataclasses.dataclass(frozen=True)
class FunctionProfile:
    """One deployable function in a scenario's mix.

    ``work_us`` is the median per-invocation CPU cost; when
    ``heavy_tail_alpha`` is set the runner replaces the constant with a
    Pareto sampler of that shape pinned to the same median.

    ``edges`` names the function's downstream chain edges
    (:class:`~repro.core.workload.ChainEdge`): completing an invocation
    triggers each edge's target with its probability, making the mix a
    chain/DAG workload.  Chain-only targets (weight 0) still belong in
    the scenario's ``functions`` so they get deployed.
    """
    name: str
    work_us: float = AES_600B_WORK_US
    payload_bytes: int = 600
    response_bytes: int = 628
    weight: float = 1.0
    scale: int = 1
    max_cores: int = 2
    heavy_tail_alpha: Optional[float] = None
    edges: Tuple[ChainEdge, ...] = ()


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    """Recipe for an arrival process, parameterised by the offered rate so
    one spec serves every point of a load sweep.

    kinds: ``poisson`` | ``bursty`` | ``diurnal`` | ``trace``.
    """
    kind: str = "poisson"
    # bursty: fraction of the aggregate rate carried by the quiet state,
    # and burst/quiet dwell times
    quiet_frac: float = 0.25
    mean_quiet_s: float = 0.20
    mean_burst_s: float = 0.05
    # diurnal
    amplitude: float = 0.8
    period_s: float = 1.0
    # trace: absolute timestamps (rate argument ignored)
    trace_s: Tuple[float, ...] = ()
    time_scale: float = 1.0

    def build(self, rate_rps: float) -> ArrivalProcess:
        if self.kind == "poisson":
            return PoissonArrivals(rate_rps)
        if self.kind == "bursty":
            # split the aggregate rate so the time-average equals rate_rps
            tot = self.mean_quiet_s + self.mean_burst_s
            quiet = rate_rps * self.quiet_frac
            burst = (rate_rps * tot - quiet * self.mean_quiet_s) / self.mean_burst_s
            return BurstyArrivals(quiet, burst, self.mean_quiet_s,
                                  self.mean_burst_s)
        if self.kind == "diurnal":
            return DiurnalArrivals(rate_rps, self.amplitude, self.period_s)
        if self.kind == "trace":
            return TraceReplay(self.trace_s, self.time_scale)
        raise ValueError(f"unknown arrival kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class AutoscalerSpec:
    """Recipe for putting an autoscaler in a scenario's control loop.

    ``policy`` picks the :class:`~repro.core.autoscaler.ScalePolicy`
    implementation: ``"queue-depth"`` (fixed ``period_s``) or
    ``"lead-time"`` (control period and scale-up headroom derived from
    the backend's ColdStartModel; ``period_s`` is ignored).  The runner
    builds one fresh Autoscaler per (rate, seed) run and records its
    scale-event telemetry into the artifact (schema v3).
    """
    policy: str = "lead-time"
    min_replicas: int = 1
    max_replicas: int = 16
    target_inflight_per_replica: float = 4.0
    scale_down_hysteresis: float = 0.5
    period_s: float = 0.25              # queue-depth control period
    period_floor_s: float = 0.01        # lead-time period bounds
    period_ceil_s: float = 0.25
    lead_mult: float = 2.0

    def build(self) -> ScalePolicy:
        common = dict(
            min_replicas=self.min_replicas, max_replicas=self.max_replicas,
            target_inflight_per_replica=self.target_inflight_per_replica,
            scale_down_hysteresis=self.scale_down_hysteresis)
        if self.policy == "queue-depth":
            return QueueDepthPolicy(period_s=self.period_s, **common)
        if self.policy == "lead-time":
            return LeadTimePolicy(period_floor_s=self.period_floor_s,
                                  period_ceil_s=self.period_ceil_s,
                                  lead_mult=self.lead_mult, **common)
        raise ValueError(f"unknown autoscaler policy {self.policy!r}")


@dataclasses.dataclass(frozen=True)
class SearchSpec:
    """Recipe for the adaptive SLO-knee search that replaces hand-sized
    rate grids on open-mode scenarios.

    The runner drives one :class:`~repro.core.workload.KneeSearch` per
    (backend, seed): coarse exponential bracketing at low resolution
    (``bracket_duration_frac`` of the scenario duration), then SLO-aware
    geometric bisection until the bracket's relative width is within
    ``rel_tol`` — all under a hard ``max_probes`` open-loop sample budget.
    Smoke runs use the coarser ``smoke_rel_tol``/``smoke_max_probes``.

    ``rate0`` seeds the bracket; ``None`` (the default) calibrates it
    from a cheap closed-loop warm-latency measurement, so a brand-new
    backend needs zero hand-measured rate entries.  ``rate0_frac``
    down-scales that seed: knee-claim scenarios start near the capacity
    estimate (fast bracketing), satellite scenarios start well under it
    so even a two-probe smoke budget lands one comfortable-load probe
    whose latency row is a sane representative.
    """
    rate0: Optional[float] = None
    rate0_frac: float = 1.0
    growth: float = 2.0
    shrink: float = 0.75
    rel_tol: float = 0.10
    max_probes: int = 12
    smoke_rel_tol: float = 0.15
    smoke_max_probes: int = 8
    bracket_duration_frac: float = 0.4
    rate_floor: float = 25.0
    rate_ceiling: float = 64000.0

    def rel_tol_for(self, smoke: bool) -> float:
        return self.smoke_rel_tol if smoke else self.rel_tol

    def max_probes_for(self, smoke: bool) -> int:
        return self.smoke_max_probes if smoke else self.max_probes


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Fleet topology + provisioning model for ``mode="fleet"`` scenarios.

    ``n_workers`` is the **simulated** cluster size (not the runner's
    ``--workers`` process parallelism).  ``placement`` /
    ``distribution`` name the primary gateway policy and image
    distribution (see ``repro.fleet``); ``compare_*`` adds variants the
    runner executes side by side, so one scenario can pit tree against
    naive provisioning or least-loaded against locality placement.

    A non-zero ``storm_replicas`` schedules a provisioning storm at
    ``storm_t_frac`` of the run: that many replicas of a fresh function
    spread across the fleet, every worker paying an image transfer
    (``image_mb`` over ``origin_gbps``/``peer_gbps``) before its
    backend's deploy path.  ``rates[backend]`` is interpreted
    **per worker**; the gateway admits ``rate * n_workers``.

    ``spread`` places the warm mix: ``"all"`` deploys every function on
    every worker; ``"zipf"`` gives the rank-r function a
    popularity-proportional worker subset (min 2), leaving the gateway's
    pressure-driven expansion to widen hot functions mid-run.
    ``spill_load`` is the outstanding-per-core threshold that triggers
    expansion (``None`` disables it).
    """
    n_workers: int = 32
    placement: str = "least-loaded"
    compare_placements: Tuple[str, ...] = ()
    distribution: str = "tree"
    compare_distributions: Tuple[str, ...] = ()
    storm_replicas: int = 0
    storm_t_frac: float = 0.25
    image_mb: float = 256.0
    origin_gbps: float = 10.0
    peer_gbps: float = 10.0
    fanout: int = 2
    spread: str = "all"            # "all" | "zipf"
    spill_load: Optional[float] = 8.0

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.spread not in ("all", "zipf"):
            raise ValueError(f"unknown spread {self.spread!r}")
        if not 0.0 <= self.storm_t_frac < 1.0:
            raise ValueError(
                f"storm_t_frac must be in [0, 1), got {self.storm_t_frac}")

    def placements(self) -> Tuple[str, ...]:
        return (self.placement,) + tuple(self.compare_placements)

    def distributions(self) -> Tuple[str, ...]:
        return (self.distribution,) + tuple(self.compare_distributions)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A complete experiment: mix + arrivals + duration + backend matrix.

    modes:
      * ``closed`` — n_requests sequential invocations per function
        (paper Fig 5 methodology); ``rates`` unused.
      * ``open``   — adaptive SLO-knee search per backend (the default:
        ``search_spec()``), or an open-loop sweep over ``rates[backend]``
        when the scenario pins explicit grids (paper Fig 6 methodology,
        exact-reproduction runs).
      * ``storm``  — ``storm_functions`` concurrent deploy+first-invoke
        (cold-start storm; FaaSNet's provisioning regime).
      * ``mixed``  — steady warm traffic at ``rates[backend][0]`` plus a
        ``storm_functions`` provisioning storm on the same worker mid-run
        (warm-path interference; cold/warm path coupling).
      * ``fleet``  — an N-worker cluster behind a gateway
        (``repro.fleet``), topology from ``fleet``; warm traffic at
        ``rates[backend][0]`` **per worker**, optional mid-run
        provisioning storm with image distribution (FaaSNet regime),
        placement/distribution variants side by side.
      * ``chain``  — chained/DAG traffic at ``rates[backend][0]``: the
        mix's ``edges`` expand each root arrival into its chain of
        hops; when ``fusion`` is set the same seeds also run fused
        (selected edges co-located in the caller's sandbox) and the
        result carries the fused-vs-unfused comparison.

    An optional ``autoscaler`` spec puts a backend-aware autoscaler in
    the control loop of ``open``/``mixed`` runs; its scale-event
    telemetry (reaction times, replica timeline, cold starts) lands in
    the artifact.
    """
    name: str
    description: str
    mode: str = "open"
    functions: Tuple[FunctionProfile, ...] = (FunctionProfile("aes"),)
    arrival: ArrivalSpec = ArrivalSpec("poisson")
    rates: Optional[Dict[str, Tuple[float, ...]]] = None
    smoke_rates: Optional[Dict[str, Tuple[float, ...]]] = None
    search: Optional[SearchSpec] = None
    duration_s: float = 1.0
    warmup_frac: float = 0.2
    n_requests: int = 100
    seeds: Tuple[int, ...] = (0,)
    n_cores: int = 10
    slo_p99_ms: float = 10.0
    storm_functions: int = 16
    fleet: Optional[FleetSpec] = None     # mode="fleet" topology
    fusion: Optional[FusionPlan] = None   # mode="chain" fusion pass
    autoscaler: Optional[AutoscalerSpec] = None
    backends: Tuple[str, ...] = DEFAULT_BACKENDS
    # (baseline, treatment) pair the paper-claim reductions are computed
    # from; claims are skipped when the pair is not part of the run.
    claims_pair: Tuple[str, str] = DEFAULT_CLAIMS_PAIR
    claims_kind: Optional[str] = None     # "fig5" | "fig6" | "coldstart"
    tags: Tuple[str, ...] = ()

    def search_spec(self) -> Optional[SearchSpec]:
        """The effective knee-search spec, or ``None`` when this scenario
        runs on a rate grid.

        Adaptive search is the default for open-mode scenarios: a
        scenario that pins explicit ``rates`` (exact-reproduction runs,
        the grid-mode regression anchor) keeps the grid sweep, and
        non-open modes never search."""
        if self.mode != "open" or self.rates:
            return None
        return self.search or SearchSpec()

    def weights(self) -> List[float]:
        return [f.weight for f in self.functions]

    def fn_names(self) -> List[str]:
        return [f.name for f in self.functions]

    def chain_edges(self) -> Dict[str, Tuple[ChainEdge, ...]]:
        """The mix's chain graph: function name -> downstream edges
        (empty when no profile declares edges)."""
        return {p.name: tuple(p.edges) for p in self.functions if p.edges}

    def load_spec(self, rate: float, duration_s: float,
                  fusion: Optional[FusionPlan] = None) -> LoadSpec:
        """The :func:`repro.core.workload.drive` load for one open-loop
        run of this scenario at ``rate`` (mix, arrivals, warmup, and —
        when the mix declares edges — its chain graph).  ``fusion``
        optionally applies a fusion pass to the chained load."""
        chains = self.chain_edges()
        return LoadSpec(arrivals=self.arrival.build(rate),
                        functions=tuple(self.fn_names()),
                        weights=tuple(self.weights()),
                        duration_s=duration_s,
                        warmup_frac=self.warmup_frac,
                        chains=chains or None,
                        fusion=fusion)

    def rates_for(self, backend: str, smoke: bool = False) -> Sequence[float]:
        """Rate grid for one backend; the ``"*"`` key is the fallback grid
        for backends without an explicit entry (lets a scenario run
        against any registered backend).

        Falling through to ``"*"`` when the table carries explicit
        per-backend grids emits a one-line warning naming the backend: a
        fallback grid is sized for somebody else's knee, and silently
        reusing it has hidden backends sweeping entirely past their cliff
        (quark, pre-PR 3).  A table whose *only* key is ``"*"`` (e.g. the
        trace-replay scenario, where the trace fixes the rate) is a
        deliberate one-grid-for-all and stays silent."""
        table = (self.smoke_rates if smoke and self.smoke_rates
                 else self.rates) or {}
        if backend in table:
            return table[backend]
        fallback = table.get("*", ())
        if fallback and any(k != "*" for k in table):
            warnings.warn(
                f"scenario {self.name!r}: backend {backend!r} has no "
                f"explicit rate grid; falling back to the '*' grid "
                f"{tuple(fallback)} — size a knee-specific grid for it",
                RuntimeWarning, stacklevel=2)
        return fallback

def zipf_mix(n_functions: int, zipf_a: float = 1.5,
             work_us: float = AES_600B_WORK_US,
             prefix: str = "f") -> Tuple[FunctionProfile, ...]:
    """A multi-tenant mix with Zipf-distributed popularity (Shahrad et al.:
    most functions are rarely invoked)."""
    ranks = range(1, n_functions + 1)
    return tuple(FunctionProfile(name=f"{prefix}{i}", work_us=work_us,
                                 weight=float(r) ** (-zipf_a))
                 for i, r in enumerate(ranks))
