"""Machine-readable bench artifacts (``BENCH_<suite>.json``).

One artifact per suite run: every scenario's per-backend curves, latency
histograms, knee/SLO metrics, and paper-claim deltas, plus a flat
``metrics`` table (the old CSV rows) so regression tooling can diff runs
without knowing scenario internals.  ``validate_artifact`` is the schema
gate used both before writing and by the tests.
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

# v2: scenario entries record the configured backend matrix
# (``backend_set``) and the (baseline, treatment) ``claims_pair`` next to
# the per-backend results, so artifact consumers never have to assume the
# containerd/junctiond pair.
# v3: control-plane telemetry — a per-backend result may carry an
# ``autoscaler`` block (scale-event counts, scale-up reaction-time
# percentiles, cold starts, replica timeline); when present it must have
# the keys regression tooling reads.  Older artifacts (v1/v2, the
# trendline baseline case) still validate: version-specific keys are
# required only when the document declares that schema_version.
# v4: adaptive knee search — an open-mode per-backend result may carry a
# ``search`` block (the spec the search ran with, total probe count,
# per-seed knees, converged flag, and the recorded per-seed probe
# traces); grid-mode results carry none.  Either way the representative
# latency row is tracked by index (``knee_row``), never by re-matching
# the knee rate by float equality.
# v5: fleet mode — a per-backend result may carry a ``fleet`` block
# (cluster size, primary placement/distribution, per-variant results
# over the placement x distribution grid, each with a per-worker
# telemetry list: placement counts, latency percentiles, storm pull
# timelines, autoscaler reaction summaries).  When the scenario compares
# tree vs naive distribution the block also carries
# ``tree_provisioning_speedup`` (naive/tree time-to-full-capacity).
# v6: chain mode — a per-backend result with ``mode == "chain"`` must
# carry a ``chain`` block (root counts, root latency percentiles, and
# per-hop-depth rows with the per-hop platform tax) and may carry a
# ``fusion`` block (the fused-run chain block plus the fused-vs-unfused
# ``p99_improvement`` and ``pool_efficiency`` ratios).
SCHEMA_VERSION = 6
_SUPPORTED_SCHEMA_VERSIONS = (1, 2, 3, 4, 5, 6)

_REQUIRED_TOP = ("schema_version", "suite", "duration_scale", "scenarios",
                 "metrics", "failures", "meta")
_REQUIRED_SCENARIO_V1 = ("name", "mode", "description", "backends")
_REQUIRED_SCENARIO_V2 = _REQUIRED_SCENARIO_V1 + ("backend_set",)
_REQUIRED_METRIC = ("name", "value", "derived")
_REQUIRED_AUTOSCALER = ("policy", "n_scale_events", "cold_starts",
                        "cold_path_arrivals", "reaction_p50_ms")
_REQUIRED_SEARCH = ("spec", "n_probes", "knee_rps_per_seed", "converged",
                    "trace")
_REQUIRED_FLEET = ("n_workers", "placement", "distribution", "variants")
_REQUIRED_FLEET_VARIANT = ("placement", "distribution", "workers")
_REQUIRED_FLEET_WORKER = ("worker", "n", "placements")
_REQUIRED_CHAIN = ("n_roots", "roots_completed", "root_median_ms",
                   "root_p99_ms", "hop_tax_mean_ms", "hops")
_REQUIRED_CHAIN_HOP = ("hop", "n", "median_ms", "p99_ms", "tax_mean_ms")
_REQUIRED_FUSION = ("chain", "p99_improvement", "pool_efficiency")


def latency_histogram(lat_ms: Sequence[float], n_bins: int = 24) -> Dict[str, list]:
    """Log-spaced latency histogram (µs-to-tail latencies span decades)."""
    lat = np.asarray([l for l in lat_ms if l > 0 and math.isfinite(l)])
    if len(lat) == 0:
        return {"edges_ms": [], "counts": []}
    lo, hi = float(lat.min()), float(lat.max())
    if hi <= lo:
        hi = lo * 1.001 + 1e-9
    edges = np.logspace(np.log10(lo), np.log10(hi), n_bins + 1)
    counts, _ = np.histogram(lat, bins=edges)
    return {"edges_ms": [round(float(e), 6) for e in edges],
            "counts": [int(c) for c in counts]}


def metric_row(name: str, value: float, derived: str) -> Dict[str, object]:
    v = float(value)
    return {"name": name, "value": v if math.isfinite(v) else None,
            "derived": derived}


def build_artifact(suite: str, scenarios: List[Dict[str, object]],
                   metrics: List[Dict[str, object]],
                   failures: List[Dict[str, str]],
                   duration_scale: float = 1.0,
                   meta: Optional[Dict[str, object]] = None) -> Dict[str, object]:
    return {
        "schema_version": SCHEMA_VERSION,
        "suite": suite,
        "duration_scale": duration_scale,
        "scenarios": scenarios,
        "metrics": metrics,
        "failures": failures,
        "meta": meta or {},
    }


def _fleet_problems(fleet: object) -> List[str]:
    """Schema problems inside one per-backend ``fleet`` block (v5)."""
    if not isinstance(fleet, dict):
        return [".fleet must be an object"]
    probs = [f".fleet missing {key!r}"
             for key in _REQUIRED_FLEET if key not in fleet]
    variants = fleet.get("variants")
    if variants is None:
        return probs
    if not isinstance(variants, list):
        return probs + [".fleet.variants must be a list"]
    for j, var in enumerate(variants):
        if not isinstance(var, dict):
            probs.append(f".fleet.variants[{j}] must be an object")
            continue
        probs.extend(f".fleet.variants[{j}] missing {key!r}"
                     for key in _REQUIRED_FLEET_VARIANT if key not in var)
        workers = var.get("workers")
        if workers is None:
            continue
        if not isinstance(workers, list):
            probs.append(f".fleet.variants[{j}].workers must be a list")
            continue
        for k, w in enumerate(workers):
            if not isinstance(w, dict) or any(key not in w
                                              for key in _REQUIRED_FLEET_WORKER):
                probs.append(f".fleet.variants[{j}].workers[{k}] must have "
                             f"keys {_REQUIRED_FLEET_WORKER}")
    return probs


def _chain_problems(res: dict) -> List[str]:
    """Schema problems inside one ``mode == "chain"`` per-backend result
    (v6): the ``chain`` block is required, ``fusion`` optional."""
    probs: List[str] = []
    chain = res.get("chain")
    if not isinstance(chain, dict):
        return [".chain must be an object on chain-mode results"]

    def block(prefix: str, blk: dict) -> None:
        probs.extend(f"{prefix} missing {key!r}"
                     for key in _REQUIRED_CHAIN if key not in blk)
        hops = blk.get("hops")
        if not isinstance(hops, list):
            return
        for j, row in enumerate(hops):
            if not isinstance(row, dict) or any(key not in row
                                                for key in _REQUIRED_CHAIN_HOP):
                probs.append(f"{prefix}.hops[{j}] must have keys "
                             f"{_REQUIRED_CHAIN_HOP}")

    block(".chain", chain)
    fusion = res.get("fusion")
    if fusion is not None:
        if not isinstance(fusion, dict):
            probs.append(".fusion must be an object")
        else:
            probs.extend(f".fusion missing {key!r}"
                         for key in _REQUIRED_FUSION if key not in fusion)
            if isinstance(fusion.get("chain"), dict):
                block(".fusion.chain", fusion["chain"])
            elif "chain" in fusion:
                probs.append(".fusion.chain must be an object")
    return probs


def validate_artifact(doc: Dict[str, object]) -> None:
    """Raise ValueError describing every schema violation found."""
    problems: List[str] = []
    if not isinstance(doc, dict):
        raise ValueError("artifact must be a JSON object")
    for key in _REQUIRED_TOP:
        if key not in doc:
            problems.append(f"missing top-level key {key!r}")
    version = doc.get("schema_version")
    if version not in _SUPPORTED_SCHEMA_VERSIONS:
        problems.append(f"schema_version must be one of "
                        f"{_SUPPORTED_SCHEMA_VERSIONS}, got {version!r}")
    required_scenario = (_REQUIRED_SCENARIO_V1 if version == 1
                         else _REQUIRED_SCENARIO_V2)
    if not isinstance(doc.get("scenarios"), list):
        problems.append("scenarios must be a list")
    else:
        for i, sc in enumerate(doc["scenarios"]):
            if not isinstance(sc, dict):
                problems.append(f"scenarios[{i}] must be an object")
                continue
            for key in required_scenario:
                if key not in sc:
                    problems.append(f"scenarios[{i}] ({sc.get('name', '?')}) "
                                    f"missing {key!r}")
            backends = sc.get("backends")
            if isinstance(backends, dict):
                for b, res in backends.items():
                    if not isinstance(res, dict):
                        problems.append(f"scenarios[{i}].backends[{b}] "
                                        "must be an object")
                        continue
                    asc = res.get("autoscaler")
                    if version in (3, 4, 5, 6) and asc is not None:
                        if not isinstance(asc, dict):
                            problems.append(f"scenarios[{i}].backends[{b}]"
                                            ".autoscaler must be an object")
                        else:
                            for key in _REQUIRED_AUTOSCALER:
                                if key not in asc:
                                    problems.append(
                                        f"scenarios[{i}].backends[{b}]"
                                        f".autoscaler missing {key!r}")
                    search = res.get("search")
                    if version in (4, 5, 6) and search is not None:
                        if not isinstance(search, dict):
                            problems.append(f"scenarios[{i}].backends[{b}]"
                                            ".search must be an object")
                        else:
                            for key in _REQUIRED_SEARCH:
                                if key not in search:
                                    problems.append(
                                        f"scenarios[{i}].backends[{b}]"
                                        f".search missing {key!r}")
                    fleet = res.get("fleet")
                    if version in (5, 6) and fleet is not None:
                        problems.extend(
                            f"scenarios[{i}].backends[{b}]{p}"
                            for p in _fleet_problems(fleet))
                    if version == 6 and res.get("mode") == "chain":
                        problems.extend(
                            f"scenarios[{i}].backends[{b}]{p}"
                            for p in _chain_problems(res))
            else:
                problems.append(f"scenarios[{i}].backends must be an object")
            backend_set = sc.get("backend_set")
            if backend_set is not None and not (
                    isinstance(backend_set, list)
                    and all(isinstance(b, str) for b in backend_set)):
                problems.append(f"scenarios[{i}].backend_set must be a "
                                "list of backend names")
    if not isinstance(doc.get("metrics"), list):
        problems.append("metrics must be a list")
    else:
        for i, row in enumerate(doc["metrics"]):
            if not isinstance(row, dict) or any(k not in row
                                                for k in _REQUIRED_METRIC):
                problems.append(f"metrics[{i}] must have keys "
                                f"{_REQUIRED_METRIC}")
    if not isinstance(doc.get("failures"), list):
        problems.append("failures must be a list")
    if problems:
        raise ValueError("invalid bench artifact: " + "; ".join(problems))


def write_artifact(path: str, doc: Dict[str, object]) -> None:
    validate_artifact(doc)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")


def metrics_csv(doc: Dict[str, object]) -> str:
    """The legacy ``name,us_per_call,derived`` view of an artifact."""
    lines = ["name,value,derived"]
    for row in doc.get("metrics", []):
        v = row["value"]
        v_str = f"{v:.3f}" if isinstance(v, (int, float)) else "nan"
        lines.append(f"{row['name']},{v_str},{row['derived']}")
    return "\n".join(lines)
