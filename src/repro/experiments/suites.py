"""The scenario registry and named suites.

Scenario backlog rationale: production FaaS platforms are defined by
workload diversity — paper Fig 5/6 cover a single warm function, FaaSNet
motivates bursty provisioning storms, Shahrad et al. motivate long-tail
multi-tenancy, and model serving adds ms-scale service times where the
runtime overhead question changes shape.  Every scenario runs across its
backend matrix (the paper's containerd-vs-junctiond pair by default;
``--backends`` widens it to any registered set, e.g. quark/wasm) with
paper-claim deltas always computed from the scenario's claims pair.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

from repro.core.latency import AES_600B_WORK_US
from repro.core.workload import ChainEdge, FusionPlan
from repro.experiments.scenario import (ArrivalSpec, AutoscalerSpec,
                                        FleetSpec, FunctionProfile, Scenario,
                                        SearchSpec, zipf_mix)

# Open-mode scenarios default to the adaptive SLO-knee search (no
# per-backend rate grids to hand-measure; see SearchSpec): the paper-fig6
# knee claim gets the fine default tolerance, the satellite scenarios get
# a coarser/cheaper spec — their job is behaviour at load, not a tight
# knee estimate (under 20x MMPP bursts the SLO knee is legitimately 0 at
# short durations), so smoke caps them at two probes: one calibrated
# bracketing probe plus its full-resolution confirmation, which is what
# the old one-rate smoke grids bought, minus the hand-sizing.
# ``multi-tenant-mix`` deliberately keeps its measured grids as the
# grid-mode regression anchor (exact-reproduction path).
_COARSE_SEARCH = SearchSpec(rate0_frac=0.15, rel_tol=0.20, max_probes=6,
                            smoke_rel_tol=0.35, smoke_max_probes=2)

_DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# analytic decode-step service times (µs) used when no dry-run roofline
# record exists; overridden by repro.launch.dryrun artifacts when present
_ENDPOINT_FALLBACK_US = {"qwen3-1.7b": 450.0, "mixtral-8x7b": 1800.0}


def _roofline_step_us(arch: str, shape: str = "decode_32k") -> float:
    path = os.path.join(_DRYRUN_DIR, f"{arch}__{shape}__pod16x16.json")
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        roof = rec.get("roofline")
        if roof:
            return float(roof["step_time_s"]) * 1e6
    return _ENDPOINT_FALLBACK_US[arch]


def _trace_burst_train(n_bursts: int = 6, burst_n: int = 120,
                       spacing_s: float = 0.18,
                       intra_gap_s: float = 0.0004) -> tuple:
    """Synthetic provisioning-trace: tight request trains every spacing_s
    (deterministic stand-in for a recorded Azure/FaaSNet trace slice)."""
    out: List[float] = []
    for b in range(n_bursts):
        t0 = 0.05 + b * spacing_s
        out.extend(t0 + i * intra_gap_s for i in range(burst_n))
    return tuple(round(t, 6) for t in out)


def _pipeline_mix() -> tuple:
    """3-hop ingest -> transform -> store pipeline: only the root takes
    gateway traffic (weight 1); the downstream hops are chain-only
    targets (weight 0) that still deploy with the mix."""
    return (
        FunctionProfile("ingest", max_cores=8,
                        edges=(ChainEdge("transform"),)),
        FunctionProfile("transform", max_cores=8, weight=0.0,
                        edges=(ChainEdge("store"),)),
        FunctionProfile("store", max_cores=8, weight=0.0,
                        response_bytes=128),
    )


_CHAIN_RATES = {"containerd": (300.0,), "junctiond": (900.0,),
                "quark": (220.0,), "wasm": (400.0,),
                "firecracker": (280.0,), "gvisor": (260.0,),
                "*": (300.0,)}


def build_scenarios() -> Dict[str, Scenario]:
    aes = FunctionProfile("aes")
    scenarios = [
        Scenario(
            name="paper-fig5",
            description="100 sequential AES(600B) invocations per seed; "
                        "paper Fig 5 latency-distribution claims",
            mode="closed", functions=(aes,), n_requests=100,
            seeds=tuple(range(8)), claims_kind="fig5",
            tags=("paper", "latency")),
        Scenario(
            name="paper-fig6",
            description="Open-loop Poisson load sweep to the SLO knee; "
                        "paper Fig 6 throughput/latency claims",
            mode="open", functions=(FunctionProfile("aes", max_cores=8),),
            arrival=ArrivalSpec("poisson"),
            search=SearchSpec(rate0_frac=0.5),
            duration_s=1.5, seeds=(3,), slo_p99_ms=10.0, claims_kind="fig6",
            tags=("paper", "throughput")),
        Scenario(
            name="cold-start-storm",
            description="Concurrent deploy+first-invoke storm (FaaSNet's "
                        "bursty provisioning regime) + paper instance-init",
            mode="storm", functions=(aes,), storm_functions=16,
            seeds=(0, 1, 2), claims_kind="coldstart",
            tags=("coldstart", "provisioning")),
        Scenario(
            name="multi-tenant-mix",
            description="32 functions, Zipf(1.5) popularity, one open-loop "
                        "stream on a 36-core worker (Shahrad long-tail mix); "
                        "pinned rate grids (grid-mode regression anchor)",
            mode="open", functions=zipf_mix(32),
            arrival=ArrivalSpec("poisson"),
            # the one scenario that keeps hand-measured grids: exercises
            # the exact-reproduction grid path + the '*' fallback warning
            # so search mode can never silently become the only executor
            rates={"containerd": (600.0, 1000.0, 1400.0),
                   "junctiond": (1500.0, 4000.0, 8000.0),
                   "quark": (400.0, 700.0, 1000.0),
                   "wasm": (700.0, 1200.0, 1700.0),
                   "firecracker": (500.0, 900.0, 1300.0),
                   "gvisor": (450.0, 800.0, 1200.0),
                   "*": (600.0, 1000.0, 1400.0)},
            smoke_rates={"containerd": (1000.0,), "junctiond": (4000.0,),
                         "quark": (700.0,), "wasm": (1200.0,),
                         "firecracker": (900.0,), "gvisor": (800.0,),
                         "*": (1000.0,)},
            duration_s=1.0, n_cores=36, seeds=(0,), slo_p99_ms=10.0,
            tags=("multitenant",)),
        Scenario(
            name="bursty-burst",
            description="MMPP-2 bursty arrivals: quiet floor with 20x "
                        "bursts; tests knee robustness to burstiness",
            mode="open", functions=(FunctionProfile("aes", max_cores=8),),
            arrival=ArrivalSpec("bursty", quiet_frac=0.25,
                                mean_quiet_s=0.20, mean_burst_s=0.05),
            search=_COARSE_SEARCH,
            duration_s=1.2, seeds=(1,), slo_p99_ms=10.0,
            tags=("bursty",)),
        Scenario(
            name="diurnal-drift",
            description="Sinusoidal rate drift (diurnal pattern compressed "
                        "to sim time): latency across the peak/trough",
            mode="open", functions=(FunctionProfile("aes", max_cores=8),),
            arrival=ArrivalSpec("diurnal", amplitude=0.8, period_s=0.5),
            search=_COARSE_SEARCH,
            duration_s=1.0, seeds=(2,), slo_p99_ms=10.0,
            tags=("diurnal",)),
        Scenario(
            name="heavy-tail-mix",
            description="Pareto(1.5) per-invocation work pinned to the AES "
                        "median: heavy-tailed payloads vs the tail claims",
            mode="open",
            functions=(FunctionProfile("aes-ht", work_us=AES_600B_WORK_US,
                                       max_cores=8, heavy_tail_alpha=1.5),),
            arrival=ArrivalSpec("poisson"),
            search=_COARSE_SEARCH,
            duration_s=1.0, seeds=(4,), slo_p99_ms=25.0,
            tags=("heavytail",)),
        Scenario(
            name="trace-replay",
            description="Deterministic burst-train trace replay "
                        "(provisioning-trace stand-in, ~640 rps mean)",
            mode="open", functions=(FunctionProfile("aes", max_cores=8),),
            arrival=ArrivalSpec("trace", trace_s=_trace_burst_train()),
            rates={"*": (0.0,)},      # the trace fixes the rate
            duration_s=1.2, seeds=(0,), slo_p99_ms=25.0,
            tags=("trace",)),
        Scenario(
            name="autoscale-burst",
            description="MMPP-2 bursts against an autoscaled function: "
                        "gates on scale-up reaction time (pressure onset "
                        "-> capacity ready; FaaSNet's production metric)",
            mode="open", functions=(FunctionProfile("aes", max_cores=8),),
            arrival=ArrivalSpec("bursty", quiet_frac=0.25,
                                mean_quiet_s=0.20, mean_burst_s=0.05),
            autoscaler=AutoscalerSpec(policy="lead-time",
                                      target_inflight_per_replica=2.0,
                                      max_replicas=16),
            search=_COARSE_SEARCH,
            duration_s=1.2, seeds=(1,), slo_p99_ms=15.0,
            claims_kind="autoscale",
            tags=("autoscale", "bursty", "provisioning")),
        Scenario(
            name="autoscale-diurnal",
            description="Diurnal rate drift with the lead-time autoscaler "
                        "tracking it: replica timeline follows the "
                        "sinusoid, scale events off the critical path",
            mode="open", functions=(FunctionProfile("aes", max_cores=8),),
            arrival=ArrivalSpec("diurnal", amplitude=0.8, period_s=0.5),
            autoscaler=AutoscalerSpec(policy="lead-time",
                                      target_inflight_per_replica=2.0,
                                      max_replicas=16),
            search=_COARSE_SEARCH,
            duration_s=1.0, seeds=(2,), slo_p99_ms=15.0,
            tags=("autoscale", "diurnal")),
        Scenario(
            name="mixed-cold-warm",
            description="Steady warm traffic plus a provisioning storm on "
                        "the same worker: warm-path P99 interference from "
                        "the cold path, autoscaler in the loop",
            mode="mixed", functions=(FunctionProfile("aes", max_cores=8),),
            arrival=ArrivalSpec("poisson"),
            autoscaler=AutoscalerSpec(policy="lead-time",
                                      target_inflight_per_replica=2.0,
                                      max_replicas=16),
            rates={"containerd": (600.0,), "junctiond": (2000.0,),
                   "quark": (450.0,), "wasm": (700.0,),
                   "firecracker": (550.0,), "gvisor": (500.0,),
                   "*": (600.0,)},
            duration_s=3.0, warmup_frac=0.1, storm_functions=16,
            seeds=(0,), slo_p99_ms=15.0, claims_kind="interference",
            tags=("mixed", "coldstart", "autoscale", "provisioning")),
        Scenario(
            name="fleet-storm",
            description="32-worker fleet behind a gateway: a 1000-replica "
                        "provisioning storm lands mid-run, FaaSNet tree "
                        "distribution vs naive registry pulls, warm "
                        "traffic riding along (rates are per worker)",
            mode="fleet", functions=(FunctionProfile("aes", max_cores=8),),
            arrival=ArrivalSpec("poisson"),
            fleet=FleetSpec(n_workers=32, placement="least-loaded",
                            distribution="tree",
                            compare_distributions=("naive",),
                            storm_replicas=1000, storm_t_frac=0.25),
            rates={"containerd": (300.0,), "junctiond": (1200.0,),
                   "quark": (220.0,), "wasm": (400.0,),
                   "firecracker": (280.0,), "gvisor": (260.0,),
                   "*": (300.0,)},
            duration_s=4.0, warmup_frac=0.1, seeds=(0,), slo_p99_ms=15.0,
            claims_kind="fleet",
            tags=("fleet", "provisioning", "coldstart")),
        Scenario(
            name="fleet-zipf-diurnal",
            description="Zipf(1.5) tenants with diurnal drift across a "
                        "32-worker fleet, per-worker lead-time "
                        "autoscalers; least-loaded vs round-robin vs "
                        "locality placement (rates are per worker)",
            mode="fleet", functions=zipf_mix(12, prefix="t"),
            arrival=ArrivalSpec("diurnal", amplitude=0.8, period_s=0.5),
            fleet=FleetSpec(n_workers=32, placement="least-loaded",
                            compare_placements=("round-robin", "locality"),
                            distribution="tree", spread="zipf"),
            autoscaler=AutoscalerSpec(policy="lead-time",
                                      target_inflight_per_replica=2.0,
                                      max_replicas=16),
            rates={"containerd": (250.0,), "junctiond": (1000.0,),
                   "quark": (180.0,), "wasm": (320.0,),
                   "firecracker": (230.0,), "gvisor": (210.0,),
                   "*": (250.0,)},
            duration_s=2.0, warmup_frac=0.15, seeds=(0,), slo_p99_ms=25.0,
            tags=("fleet", "multitenant", "diurnal", "autoscale")),
        Scenario(
            name="chain-tax",
            description="3-hop ingest->transform->store pipeline: every "
                        "non-fused hop re-enters admission and pays the "
                        "full gateway+netstack station walk, so the "
                        "per-hop platform tax compounds with depth; "
                        "claims the treatment's per-hop overhead is a "
                        "fraction of the baseline's",
            mode="chain", functions=_pipeline_mix(),
            arrival=ArrivalSpec("poisson"),
            rates=_CHAIN_RATES,
            duration_s=2.0, warmup_frac=0.1, seeds=(0, 1),
            slo_p99_ms=25.0, claims_kind="chain",
            tags=("chain", "pipeline")),
        Scenario(
            name="chain-fused",
            description="Same 3-hop pipeline with a FusionPlan co-locating "
                        "both edges: fused hops skip gateway+netstack and "
                        "run inside the caller's sandbox; gates on the "
                        "end-to-end P99 improvement and pool efficiency "
                        "of fusion on the baseline backend",
            mode="chain", functions=_pipeline_mix(),
            arrival=ArrivalSpec("poisson"),
            fusion=FusionPlan(edges=(("ingest", "transform"),
                                     ("transform", "store"))),
            rates=_CHAIN_RATES,
            duration_s=2.0, warmup_frac=0.1, seeds=(0, 1),
            slo_p99_ms=25.0, claims_kind="chain_fusion",
            tags=("chain", "pipeline", "fusion")),
        Scenario(
            name="model-endpoint",
            description="Model decode steps as junctiond functions: how "
                        "much of an ms-scale endpoint budget the FaaS "
                        "runtime costs (reuses serving/ dry-run rooflines)",
            mode="closed",
            functions=tuple(
                FunctionProfile(arch, work_us=_roofline_step_us(arch),
                                payload_bytes=2048, response_bytes=2048)
                for arch in sorted(_ENDPOINT_FALLBACK_US)),
            n_requests=50, seeds=(5, 6), tags=("serving", "endpoint")),
    ]
    return {sc.name: sc for sc in scenarios}


SUITES: Dict[str, List[str]] = {
    # full matrix at default durations — the acceptance gate
    "scenarios": ["paper-fig5", "paper-fig6", "cold-start-storm",
                  "multi-tenant-mix", "bursty-burst", "diurnal-drift",
                  "heavy-tail-mix", "trace-replay", "autoscale-burst",
                  "autoscale-diurnal", "mixed-cold-warm", "fleet-storm",
                  "fleet-zipf-diurnal", "chain-tax", "chain-fused",
                  "model-endpoint"],
    # short CI gate: same scenarios, smoke rates + scaled durations
    "smoke": ["paper-fig5", "paper-fig6", "cold-start-storm",
              "multi-tenant-mix", "bursty-burst", "diurnal-drift",
              "heavy-tail-mix", "autoscale-burst", "autoscale-diurnal",
              "mixed-cold-warm", "fleet-storm", "chain-tax", "chain-fused",
              "model-endpoint"],
    # the chain/fusion pair (pipeline workloads)
    "chain": ["chain-tax", "chain-fused"],
    # just the paper's headline figures
    "paper": ["paper-fig5", "paper-fig6", "cold-start-storm"],
    # the control-plane trio (autoscaler-in-the-loop)
    "autoscale": ["autoscale-burst", "autoscale-diurnal", "mixed-cold-warm"],
    # the fleet pair (gateway + N workers + image distribution)
    "fleet": ["fleet-storm", "fleet-zipf-diurnal"],
}

SMOKE_DURATION_SCALE = 0.33


def get_scenario(name: str) -> Scenario:
    reg = build_scenarios()
    if name not in reg:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(reg)}")
    return reg[name]


def get_suite(name: str) -> List[Scenario]:
    if name not in SUITES:
        raise KeyError(f"unknown suite {name!r}; have {sorted(SUITES)}")
    reg = build_scenarios()
    return [reg[n] for n in SUITES[name]]
