"""ExperimentRunner: executes :class:`Scenario` specs across the backend
matrix and assembles the machine-readable bench artifact.

Execution is factored into module-level per-mode functions so (scenario,
backend) work items can ship to parallel worker processes unchanged; the
runner itself only schedules work and reduces results into the artifact
(claims, flat metrics, histograms).
"""
from __future__ import annotations

import multiprocessing
import time
import traceback
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faas import FaasdRuntime, FunctionSpec
from repro.core.simulator import Simulator
from repro.core.workload import (LatencySummary, heavy_tailed_work,
                                 knee_of_curve, run_mixed_open_loop,
                                 run_sequential)
from repro.experiments.artifacts import (build_artifact, latency_histogram,
                                         metric_row)
from repro.experiments.scenario import FunctionProfile, Scenario

PAPER_FIG5 = {"e2e_median": 37.33, "e2e_p99": 63.42,
              "exec_median": 35.3, "exec_p99": 81.0}
PAPER_FIG6 = {"throughput_ratio": 10.0, "median_speedup": 2.0,
              "p99_speedup": 3.5}
PAPER_COLDSTART_JUNCTION_MS = 3.4


# ---------------------------------------------------------------------------
# Spec -> runtime plumbing.


def _deploy_mix(rt: FaasdRuntime, functions: Sequence[FunctionProfile]) -> None:
    for prof in functions:
        work = prof.work_us
        if prof.heavy_tail_alpha is not None:
            work = heavy_tailed_work(rt.sim.rng, prof.work_us,
                                     alpha=prof.heavy_tail_alpha)
        rt.deploy_blocking(FunctionSpec(
            name=prof.name, work_us=work, payload_bytes=prof.payload_bytes,
            response_bytes=prof.response_bytes, scale=prof.scale,
            max_cores=prof.max_cores))


def _seeds(sc: Scenario, smoke: bool) -> Sequence[int]:
    return sc.seeds[:2] if smoke else sc.seeds


def _mean(xs: Sequence[float]) -> float:
    return float(np.mean(xs)) if len(xs) else float("nan")


# ---------------------------------------------------------------------------
# Mode executors.  Each returns a plain-JSON dict for one backend.


def _exec_closed(sc: Scenario, backend: str, duration_scale: float,
                 smoke: bool) -> Dict[str, object]:
    n = max(20, int(round(sc.n_requests * duration_scale)))
    if smoke:
        n = min(n, 60)
    pooled: List[float] = []
    e2e: List[LatencySummary] = []
    exe: List[LatencySummary] = []
    per_fn: Dict[str, List[float]] = {f.name: [] for f in sc.functions}
    for seed in _seeds(sc, smoke):
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
        _deploy_mix(rt, sc.functions)
        for prof in sc.functions:
            s = run_sequential(rt, prof.name, n=n)
            per_fn[prof.name].append(s.median_ms)
        e2e.append(LatencySummary.of(rt.latencies_ms()))
        exe.append(LatencySummary.of(rt.exec_latencies_ms()))
        pooled.extend(rt.latencies_ms())
    return {
        "mode": "closed",
        "n": sum(s.n for s in e2e),
        "n_per_function": n,
        "median_ms": _mean([s.median_ms for s in e2e]),
        "p99_ms": _mean([s.p99_ms for s in e2e]),
        "mean_ms": _mean([s.mean_ms for s in e2e]),
        "p999_ms": _mean([s.p999_ms for s in e2e]),
        "exec_median_ms": _mean([s.median_ms for s in exe]),
        "exec_p99_ms": _mean([s.p99_ms for s in exe]),
        "per_fn_median_ms": {k: _mean(v) for k, v in per_fn.items()},
        "hist": latency_histogram(pooled),
    }


def _exec_open(sc: Scenario, backend: str, duration_scale: float,
               smoke: bool) -> Dict[str, object]:
    duration = max(0.3, sc.duration_s * duration_scale)
    rates = sc.rates_for(backend, smoke=smoke)
    if not rates:
        # fail the cell loudly instead of emitting a zero-sample result
        # whose NaN medians would poison the JSON artifact
        raise ValueError(
            f"scenario {sc.name!r} has no rate grid for backend "
            f"{backend!r}; add rates[{backend!r}] or a '*' fallback")
    curve: List[Dict[str, object]] = []
    pooled_by_rate: Dict[float, List[float]] = {}
    for rate in rates:
        per_seed: List[Dict[str, object]] = []
        lats: List[float] = []
        for seed in _seeds(sc, smoke):
            sim = Simulator(seed=seed)
            rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
            _deploy_mix(rt, sc.functions)
            res = run_mixed_open_loop(
                rt, sc.fn_names(), sc.weights(), sc.arrival.build(rate),
                duration_s=duration, warmup_frac=sc.warmup_frac)
            lats.extend(res.pop("latencies_ms"))
            res.pop("per_fn")
            per_seed.append(res)
        row = {"nominal_rps": float(rate)}
        for key in ("offered_rps", "achieved_rps", "median_ms", "p99_ms",
                    "mean_ms", "p999_ms"):
            row[key] = _mean([r[key] for r in per_seed])
        row["n"] = int(sum(r["n"] for r in per_seed))
        row["rejected"] = int(sum(r["rejected"] for r in per_seed))
        curve.append(row)
        pooled_by_rate[float(rate)] = lats
    knee = knee_of_curve(curve, sc.slo_p99_ms)
    # representative latency point: the knee when one exists, else the
    # lowest offered rate (so over-SLO smoke runs still record latencies)
    rep = next((r for r in curve if r["nominal_rps"] == knee), None)
    if rep is None and curve:
        rep = min(curve, key=lambda r: r["nominal_rps"])
    return {
        "mode": "open",
        "duration_s": duration,
        "arrival_kind": sc.arrival.kind,
        "slo_p99_ms": sc.slo_p99_ms,
        "curve": curve,
        "knee_rps": knee,
        "median_ms": rep["median_ms"] if rep else float("nan"),
        "p99_ms": rep["p99_ms"] if rep else float("nan"),
        "n": int(sum(r["n"] for r in curve)),
        "hist": latency_histogram(
            pooled_by_rate.get(rep["nominal_rps"], []) if rep else []),
    }


def _exec_storm(sc: Scenario, backend: str, duration_scale: float,
                smoke: bool) -> Dict[str, object]:
    k = min(8, sc.storm_functions) if smoke else sc.storm_functions
    deploy_ms: List[float] = []
    invoke_ms: List[float] = []
    total_ms: List[float] = []
    for seed in _seeds(sc, smoke):
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
        t0 = sim.now
        remaining = [k]

        def one(i):
            prof = sc.functions[i % len(sc.functions)]
            spec = FunctionSpec(
                name=f"storm-{prof.name}-{i}", work_us=prof.work_us,
                payload_bytes=prof.payload_bytes,
                response_bytes=prof.response_bytes, max_cores=prof.max_cores)
            yield from rt.deploy(spec)
            deploy_ms.append((sim.now - t0) * 1e3)
            rec = yield from rt.invoke(spec.name)
            invoke_ms.append(rec.e2e * 1e3)
            total_ms.append((sim.now - t0) * 1e3)
            remaining[0] -= 1
            if remaining[0] == 0:
                sim.stop()

        for i in range(k):
            sim.process(one(i))
        sim.run()
        assert remaining[0] == 0, "storm did not drain"
    # a contention-free single deploy for the paper's instance-init claim
    sim = Simulator(seed=0)
    rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
    t0 = sim.now
    rt.deploy_blocking(FunctionSpec(name="solo"))
    single_deploy_ms = (sim.now - t0) * 1e3
    d, t = LatencySummary.of(deploy_ms), LatencySummary.of(total_ms)
    return {
        "mode": "storm",
        "functions": k,
        "n": len(total_ms),
        "single_deploy_ms": single_deploy_ms,
        "deploy_median_ms": d.median_ms,
        "deploy_p99_ms": d.p99_ms,
        "first_invoke_median_ms": LatencySummary.of(invoke_ms).median_ms,
        "median_ms": t.median_ms,       # deploy + first invoke, end to end
        "p99_ms": t.p99_ms,
        "hist": latency_histogram(total_ms),
    }


_MODES = {"closed": _exec_closed, "open": _exec_open, "storm": _exec_storm}


def _run_backend(item: Tuple[Scenario, str, float, bool]):
    """Worker entry point: one (scenario, backend) cell of the matrix."""
    sc, backend, duration_scale, smoke = item
    t0 = time.time()
    try:
        res = _MODES[sc.mode](sc, backend, duration_scale, smoke)
        res["elapsed_s"] = round(time.time() - t0, 2)
        return sc.name, backend, res, None
    except Exception:
        return sc.name, backend, None, traceback.format_exc()


# ---------------------------------------------------------------------------
# Paper-claim reductions.  Every builder works on the scenario's
# (baseline, treatment) pair — no backend names are hardcoded, so claims
# survive arbitrary backend matrices as long as the pair is part of them.


def _fig5_claims(base: dict, treat: dict) -> Dict[str, dict]:
    def red(key):
        return 100.0 * (1.0 - treat[key] / base[key])

    measured = {
        "e2e_median": red("median_ms"),
        "e2e_p99": red("p99_ms"),
        "exec_median": red("exec_median_ms"),
        "exec_p99": red("exec_p99_ms"),
    }
    return {f"{k}_reduction_pct": {"measured": round(v, 2),
                                   "paper": PAPER_FIG5[k],
                                   "delta": round(v - PAPER_FIG5[k], 2)}
            for k, v in measured.items()}


def _fig6_claims(base: dict, treat: dict) -> Dict[str, dict]:
    b_knee, t_knee = base["knee_rps"], treat["knee_rps"]
    ratio = t_knee / max(1.0, b_knee)
    claims = {
        "baseline_knee_rps": {"measured": b_knee},
        "treatment_knee_rps": {"measured": t_knee},
        "throughput_ratio": {
            "measured": round(ratio, 2), "paper": PAPER_FIG6["throughput_ratio"],
            "delta": round(ratio - PAPER_FIG6["throughput_ratio"], 2)},
    }
    b_at = next((r for r in base["curve"] if r["nominal_rps"] == b_knee), None)
    t_curve = treat["curve"]
    if b_at and t_curve and b_knee > 0:
        # latency comparison at ~1.3x the baseline's knee, as in the paper
        t_at = min(t_curve,
                   key=lambda r: abs(r["nominal_rps"] - b_knee * 1.3))
        for key, short in (("median_ms", "median_speedup"),
                           ("p99_ms", "p99_speedup")):
            x = b_at[key] / t_at[key]
            claims[short] = {"measured": round(x, 2),
                             "paper": PAPER_FIG6[short],
                             "delta": round(x - PAPER_FIG6[short], 2)}
    return claims


def _coldstart_claims(base: dict, treat: dict) -> Dict[str, dict]:
    ti, bi = treat["single_deploy_ms"], base["single_deploy_ms"]
    return {
        "treatment_init_ms": {"measured": round(ti, 3),
                              "paper": PAPER_COLDSTART_JUNCTION_MS,
                              "delta": round(ti - PAPER_COLDSTART_JUNCTION_MS, 3)},
        "baseline_coldstart_ms": {"measured": round(bi, 3)},
        "coldstart_ratio": {"measured": round(bi / ti, 1)},
        "storm_speedup": {
            "measured": round(base["median_ms"] / treat["median_ms"], 1)},
    }


_CLAIMS = {"fig5": _fig5_claims, "fig6": _fig6_claims,
           "coldstart": _coldstart_claims}


def _claim_metric_rows(sc: Scenario, backends: Dict[str, dict],
                       claims: Dict[str, dict]) -> List[dict]:
    """Flat rows; names derive from the claims pair, so the default
    containerd/junctiond pair keeps the CSV metric names stable — with
    one deliberate rename: ``coldstart_junction_init`` is now
    ``coldstart_junctiond_init`` (pair-derived), so pre-rename artifacts
    need regenerating before they can serve as compare.py baselines."""
    base_name, treat_name = sc.claims_pair
    base, treat = backends[base_name], backends[treat_name]
    rows: List[dict] = []
    if sc.claims_kind == "fig5":
        rows += [
            metric_row(f"fig5_{base_name}_median",
                       base["median_ms"] * 1e3, "us e2e"),
            metric_row(f"fig5_{treat_name}_median",
                       treat["median_ms"] * 1e3, "us e2e"),
        ]
        for name, key in (("fig5_median_reduction", "e2e_median"),
                          ("fig5_p99_reduction", "e2e_p99"),
                          ("fig5_exec_median_reduction", "exec_median"),
                          ("fig5_exec_p99_reduction", "exec_p99")):
            cl = claims[f"{key}_reduction_pct"]
            rows.append(metric_row(name, cl["measured"],
                                   f"% vs paper {cl['paper']}%"))
    elif sc.claims_kind == "fig6":
        rows += [
            metric_row(f"fig6_{base_name}_sustainable_rps",
                       claims["baseline_knee_rps"]["measured"],
                       f"rps at p99<={sc.slo_p99_ms:.0f}ms"),
            metric_row(f"fig6_{treat_name}_sustainable_rps",
                       claims["treatment_knee_rps"]["measured"],
                       f"rps at p99<={sc.slo_p99_ms:.0f}ms"),
            metric_row("fig6_throughput_ratio",
                       claims["throughput_ratio"]["measured"], "x (paper ~10x)"),
        ]
        if "median_speedup" in claims:
            rows += [
                metric_row("fig6_median_speedup_at_load",
                           claims["median_speedup"]["measured"], "x (paper ~2x)"),
                metric_row("fig6_p99_speedup_at_load",
                           claims["p99_speedup"]["measured"], "x (paper ~3.5x)"),
            ]
    elif sc.claims_kind == "coldstart":
        rows += [
            metric_row(f"coldstart_{treat_name}_init",
                       claims["treatment_init_ms"]["measured"] * 1e3,
                       "us (paper 3.4ms)"),
            metric_row(f"coldstart_{base_name}",
                       claims["baseline_coldstart_ms"]["measured"] * 1e3, "us"),
            metric_row("coldstart_ratio",
                       claims["coldstart_ratio"]["measured"],
                       f"x {base_name}/{treat_name}"),
            metric_row("coldstart_storm_speedup",
                       claims["storm_speedup"]["measured"],
                       f"x, {treat['functions']} concurrent deploys"),
        ]
    return rows


# ---------------------------------------------------------------------------


class ExperimentRunner:
    """Runs scenarios across the backend matrix, serially or in worker
    processes, and reduces results into one bench artifact."""

    def __init__(self, duration_scale: float = 1.0, smoke: bool = False,
                 workers: int = 0, verbose: bool = False):
        self.duration_scale = duration_scale
        self.smoke = smoke
        self.workers = workers
        self.verbose = verbose

    # -- execution --------------------------------------------------------
    def _execute(self, items: List[Tuple[Scenario, str, float, bool]]):
        if self.workers and self.workers > 1 and len(items) > 1:
            with multiprocessing.Pool(min(self.workers, len(items))) as pool:
                return pool.map(_run_backend, items)
        return [_run_backend(it) for it in items]

    def run_scenario(self, sc: Scenario) -> Dict[str, object]:
        doc = self.run_suite([sc], suite="adhoc")
        return doc["scenarios"][0]

    def run_suite(self, scenarios: Sequence[Scenario],
                  suite: str = "scenarios") -> Dict[str, object]:
        items = [(sc, backend, self.duration_scale, self.smoke)
                 for sc in scenarios for backend in sc.backends]
        t0 = time.time()
        raw = self._execute(items)
        by_name: Dict[str, Dict[str, dict]] = {}
        failures: List[Dict[str, str]] = []
        for name, backend, res, err in raw:
            if err is not None:
                failures.append({"scenario": name, "backend": backend,
                                 "error": err})
                if self.verbose:
                    print(f"  !! {name}/{backend} FAILED:\n{err}")
            else:
                by_name.setdefault(name, {})[backend] = res

        out_scenarios: List[Dict[str, object]] = []
        metrics: List[dict] = []
        for sc in scenarios:
            backends = by_name.get(sc.name, {})
            entry: Dict[str, object] = {
                "name": sc.name,
                "mode": sc.mode,
                "description": sc.description,
                "arrival_kind": sc.arrival.kind,
                "tags": list(sc.tags),
                "backend_set": sorted(sc.backends),
                "claims_pair": list(sc.claims_pair),
                "backends": backends,
            }
            pair_ok = all(b in backends for b in sc.claims_pair)
            if sc.claims_kind and pair_ok:
                base, treat = sc.claims_pair
                claims = _CLAIMS[sc.claims_kind](backends[base],
                                                 backends[treat])
                entry["claims"] = claims
                metrics.extend(_claim_metric_rows(sc, backends, claims))
            for backend, res in backends.items():
                if "median_ms" in res:
                    metrics.append(metric_row(
                        f"scn_{sc.name}_{backend}_median",
                        res["median_ms"] * 1e3, f"us ({sc.mode})"))
                    metrics.append(metric_row(
                        f"scn_{sc.name}_{backend}_p99",
                        res["p99_ms"] * 1e3, f"us ({sc.mode})"))
            out_scenarios.append(entry)

        meta = {
            "smoke": self.smoke,
            "workers": self.workers,
            "wall_s": round(time.time() - t0, 2),
            "n_scenarios": len(scenarios),
            "backends": sorted({b for sc in scenarios for b in sc.backends}),
        }
        return build_artifact(suite, out_scenarios, metrics, failures,
                              duration_scale=self.duration_scale, meta=meta)
