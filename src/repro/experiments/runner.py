"""ExperimentRunner: executes :class:`Scenario` specs across the backend
matrix and assembles the machine-readable bench artifact.

Execution is factored into module-level per-mode functions so (scenario,
backend) work items can ship to parallel worker processes unchanged; the
runner itself only schedules work and reduces results into the artifact
(claims, flat metrics, histograms).
"""
from __future__ import annotations

import dataclasses
import math
import multiprocessing
import time
import traceback
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.autoscaler import Autoscaler
from repro.core.faas import FaasdRuntime, FunctionSpec
from repro.core.simulator import Simulator
from repro.core.workload import (KneeSearch, LatencySummary, drive,
                                 heavy_tailed_work, knee_index_of_curve,
                                 knee_of_curve, percentile, run_sequential)
from repro.experiments.artifacts import (build_artifact, latency_histogram,
                                         metric_row)
from repro.experiments.scenario import (FleetSpec, FunctionProfile, Scenario,
                                        SearchSpec)
from repro.fleet import Cluster

PAPER_FIG5 = {"e2e_median": 37.33, "e2e_p99": 63.42,
              "exec_median": 35.3, "exec_p99": 81.0}
PAPER_FIG6 = {"throughput_ratio": 10.0, "median_speedup": 2.0,
              "p99_speedup": 3.5}
PAPER_COLDSTART_JUNCTION_MS = 3.4


# ---------------------------------------------------------------------------
# Spec -> runtime plumbing.


def _deploy_mix(rt: FaasdRuntime, functions: Sequence[FunctionProfile]) -> None:
    for prof in functions:
        work = prof.work_us
        if prof.heavy_tail_alpha is not None:
            work = heavy_tailed_work(rt.sim.rng, prof.work_us,
                                     alpha=prof.heavy_tail_alpha)
        rt.deploy_blocking(FunctionSpec(
            name=prof.name, work_us=work, payload_bytes=prof.payload_bytes,
            response_bytes=prof.response_bytes, scale=prof.scale,
            max_cores=prof.max_cores))


def _seeds(sc: Scenario, smoke: bool) -> Sequence[int]:
    return sc.seeds[:2] if smoke else sc.seeds


def _mean(xs: Sequence[float]) -> float:
    return float(np.mean(xs)) if len(xs) else float("nan")


def _finite_mean(xs: Sequence[float]) -> float:
    """Mean over the finite values only (NaN when none are): one seed
    with an undefined sample must not poison the pooled statistic."""
    finite = [x for x in xs if math.isfinite(x)]
    return float(np.mean(finite)) if finite else float("nan")


def _storm_spec(sc: Scenario, i: int) -> FunctionSpec:
    """Spec for the i-th function of a provisioning storm; every storm
    wave (first deploys, redeploys, mixed-mode storms) must build the
    identical spec or the waves measure different functions."""
    prof = sc.functions[i % len(sc.functions)]
    return FunctionSpec(
        name=f"storm-{prof.name}-{i}", work_us=prof.work_us,
        payload_bytes=prof.payload_bytes,
        response_bytes=prof.response_bytes, max_cores=prof.max_cores)


def _make_autoscaler(sc: Scenario, rt: FaasdRuntime) -> Optional[Autoscaler]:
    if sc.autoscaler is None:
        return None
    asc = Autoscaler(rt.sim, rt, sc.autoscaler.build())
    asc.run()
    return asc


def _pool_autoscaler(runs: List[Dict[str, object]]) -> Dict[str, object]:
    """Reduce per-run Autoscaler.telemetry() dicts into the artifact's
    ``autoscaler`` block: counters summed, reaction times pooled into
    percentiles, the first *eventful* run's replica timeline kept as
    representative (a search's opening bracket probe can be too short to
    trigger any scale event)."""
    reactions = [x for t in runs for x in t["reactions_ms"]]
    timeline = next((t["timeline"] for t in runs if t["timeline"]),
                    runs[0]["timeline"])
    return {
        "policy": runs[0]["policy"],
        "n_runs": len(runs),
        "n_scale_events": int(sum(t["n_scale_events"] for t in runs)),
        "n_up": int(sum(t["n_up"] for t in runs)),
        "n_down": int(sum(t["n_down"] for t in runs)),
        "n_aborted": int(sum(t["n_aborted"] for t in runs)),
        "cold_starts": int(sum(t["cold_starts"] for t in runs)),
        "cold_path_arrivals": int(sum(t["cold_path_arrivals"]
                                      for t in runs)),
        "reaction_p50_ms": percentile(reactions, 50),
        "reaction_p99_ms": percentile(reactions, 99),
        "reaction_mean_ms": _mean(reactions),
        "reactions_ms": reactions[:500],
        "timeline": timeline[:200],
    }


# ---------------------------------------------------------------------------
# Mode executors.  Each returns a plain-JSON dict for one backend.


def _exec_closed(sc: Scenario, backend: str, duration_scale: float,
                 smoke: bool) -> Dict[str, object]:
    n = max(20, int(round(sc.n_requests * duration_scale)))
    if smoke:
        n = min(n, 60)
    pooled: List[float] = []
    e2e: List[LatencySummary] = []
    exe: List[LatencySummary] = []
    per_fn: Dict[str, List[float]] = {f.name: [] for f in sc.functions}
    for seed in _seeds(sc, smoke):
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
        _deploy_mix(rt, sc.functions)
        for prof in sc.functions:
            s = run_sequential(rt, prof.name, n=n)
            per_fn[prof.name].append(s.median_ms)
        e2e.append(LatencySummary.of(rt.latencies_ms()))
        exe.append(LatencySummary.of(rt.exec_latencies_ms()))
        pooled.extend(rt.latencies_ms())
    return {
        "mode": "closed",
        "n": sum(s.n for s in e2e),
        "n_per_function": n,
        "median_ms": _mean([s.median_ms for s in e2e]),
        "p99_ms": _mean([s.p99_ms for s in e2e]),
        "mean_ms": _mean([s.mean_ms for s in e2e]),
        "p999_ms": _mean([s.p999_ms for s in e2e]),
        "exec_median_ms": _mean([s.median_ms for s in exe]),
        "exec_p99_ms": _mean([s.p99_ms for s in exe]),
        "per_fn_median_ms": {k: _mean(v) for k, v in per_fn.items()},
        "hist": latency_histogram(pooled),
    }


def _open_loop_run(sc: Scenario, backend: str, seed: int, rate: float,
                   duration: float,
                   asc_runs: List[Dict[str, object]],
                   ) -> Tuple[Dict[str, object], List[float]]:
    """One fresh-runtime open-loop run (open-loop correctness: queueing
    state never leaks across rates); returns the result row and its
    latency samples, appending autoscaler telemetry to ``asc_runs``."""
    sim = Simulator(seed=seed)
    rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
    _deploy_mix(rt, sc.functions)
    asc = _make_autoscaler(sc, rt)     # an Autoscaler is a SimObserver
    res = drive(rt, sc.load_spec(rate, duration), observer=asc)
    lats = res.pop("latencies_ms")
    res.pop("per_fn")
    if asc is not None:
        t = asc.telemetry()
        res["scale_events"] = int(t["n_scale_events"])
        res["cold_path_arrivals"] = int(t["cold_path_arrivals"])
        asc_runs.append(t)
    return res, lats


def _assemble_open(sc: Scenario, duration: float,
                   curve: List[Dict[str, object]],
                   pooled: List[List[float]], knee: float,
                   rep_idx: Optional[int],
                   asc_runs: List[Dict[str, object]]) -> Dict[str, object]:
    """Common tail of the open-mode executors: representative latency row
    (tracked by *index* — search-generated rates are not grid-aligned, so
    re-matching the knee rate by float equality silently misses) and the
    artifact's per-backend block."""
    if rep_idx is None and curve:
        # no knee anywhere: fall back to the lowest offered rate so
        # over-SLO smoke runs still record latencies — preferring
        # full-resolution rows (a low-res bracket probe under-samples
        # the tail and must not become the headline latency row when a
        # full-duration row at the same rate exists)
        candidates = [i for i, r in enumerate(curve)
                      if r.get("phase") != "bracket"] \
            or list(range(len(curve)))
        rep_idx = min(candidates, key=lambda i: curve[i]["nominal_rps"])
    rep = curve[rep_idx] if rep_idx is not None else None
    out = {
        "mode": "open",
        "duration_s": duration,
        "arrival_kind": sc.arrival.kind,
        "slo_p99_ms": sc.slo_p99_ms,
        "curve": curve,
        "knee_rps": knee,
        "knee_row": rep_idx,
        "median_ms": rep["median_ms"] if rep else float("nan"),
        "p99_ms": rep["p99_ms"] if rep else float("nan"),
        "n": int(sum(r["n"] for r in curve)),
        "hist": latency_histogram(pooled[rep_idx]
                                  if rep_idx is not None else []),
    }
    if asc_runs:
        out["autoscaler"] = _pool_autoscaler(asc_runs)
    return out


def _calibrated_rate0(sc: Scenario, backend: str, seed: int,
                      spec: SearchSpec) -> float:
    """Initial bracket rate from a cheap closed-loop warm measurement:
    roughly half the worker's aggregate service rate.  A rough guess is
    all the search needs — failing probes feed their achieved throughput
    back into the bracket as a capacity ceiling."""
    sim = Simulator(seed=seed)
    rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
    _deploy_mix(rt, sc.functions)
    s = run_sequential(rt, sc.functions[0].name, n=16)
    if not math.isfinite(s.median_ms) or s.median_ms <= 0:
        return min(max(500.0, spec.rate_floor), spec.rate_ceiling)
    est = 0.5 * sc.n_cores * 1e3 / s.median_ms
    return min(max(est, spec.rate_floor), spec.rate_ceiling)


def _exec_open_search(sc: Scenario, backend: str, duration: float,
                      smoke: bool, spec: SearchSpec) -> Dict[str, object]:
    """Adaptive knee search per (backend, seed): bracketing probes run at
    ``bracket_duration_frac`` resolution, bisection probes at full
    scenario duration; per-seed knees are pooled into ``knee_rps`` and
    every probe lands in the curve + search trace."""
    tol = spec.rel_tol_for(smoke)
    budget = spec.max_probes_for(smoke)
    curve: List[Dict[str, object]] = []
    pooled: List[List[float]] = []
    asc_runs: List[Dict[str, object]] = []
    seed_traces: List[Dict[str, object]] = []
    knees: List[float] = []
    rep_idx: Optional[int] = None
    for seed in _seeds(sc, smoke):
        rate0 = spec.rate0 if spec.rate0 is not None else \
            _calibrated_rate0(sc, backend, seed, spec)
        rate0 *= spec.rate0_frac
        base_idx = len(curve)

        def probe(rate: float, phase: str, seed=seed) -> Dict[str, object]:
            frac = spec.bracket_duration_frac if phase == "bracket" else 1.0
            d = max(0.2, duration * frac)
            res, lats = _open_loop_run(sc, backend, seed, rate, d, asc_runs)
            row = {"nominal_rps": float(rate), "seed": seed,
                   "phase": phase, "duration_s": round(d, 4), **res}
            curve.append(row)
            pooled.append(lats)
            return row

        result = KneeSearch(
            probe, sc.slo_p99_ms, rate0=rate0, growth=spec.growth,
            shrink=spec.shrink, rel_tol=tol, max_probes=budget,
            rate_floor=spec.rate_floor,
            rate_ceiling=spec.rate_ceiling).run()
        knees.append(result.knee_rps)
        ti = result.knee_trace_index()
        if rep_idx is None and ti is not None:
            rep_idx = base_idx + ti
        seed_traces.append({
            "seed": seed,
            "rate0": round(rate0, 3),
            "knee_rps": result.knee_rps,
            "lo_rps": result.lo_rps,
            "hi_rps": result.hi_rps,
            "n_probes": result.n_probes,
            "converged": result.converged,
            "probes": [{k: t[k] for k in ("rate_rps", "phase", "ok",
                                          "p99_ms", "achieved_rps",
                                          "completion_rps")}
                       for t in result.trace],
        })
    out = _assemble_open(sc, duration, curve, pooled,
                         knee=_mean(knees) if knees else 0.0,
                         rep_idx=rep_idx, asc_runs=asc_runs)
    out["search"] = {
        "spec": {"rate0": spec.rate0, "rate0_frac": spec.rate0_frac,
                 "growth": spec.growth,
                 "shrink": spec.shrink, "rel_tol": tol,
                 "max_probes": budget,
                 "bracket_duration_frac": spec.bracket_duration_frac,
                 "rate_floor": spec.rate_floor,
                 "rate_ceiling": spec.rate_ceiling},
        "n_probes": int(sum(t["n_probes"] for t in seed_traces)),
        "knee_rps_per_seed": knees,
        "converged": all(t["converged"] for t in seed_traces),
        "trace": seed_traces,
    }
    return out


def _exec_open(sc: Scenario, backend: str, duration_scale: float,
               smoke: bool) -> Dict[str, object]:
    duration = max(0.3, sc.duration_s * duration_scale)
    spec = sc.search_spec()
    if spec is not None:
        return _exec_open_search(sc, backend, duration, smoke, spec)
    rates = sc.rates_for(backend, smoke=smoke)
    if not rates:
        # fail the cell loudly instead of emitting a zero-sample result
        # whose NaN medians would poison the JSON artifact
        raise ValueError(
            f"scenario {sc.name!r} has no rate grid for backend "
            f"{backend!r}; add rates[{backend!r}], a '*' fallback, or "
            f"drop the grids to use the adaptive knee search")
    curve: List[Dict[str, object]] = []
    pooled: List[List[float]] = []
    asc_runs: List[Dict[str, object]] = []
    for rate in rates:
        per_seed: List[Dict[str, object]] = []
        lats: List[float] = []
        row_telemetry: List[Dict[str, object]] = []
        for seed in _seeds(sc, smoke):
            res, run_lats = _open_loop_run(sc, backend, seed, rate,
                                           duration, row_telemetry)
            lats.extend(run_lats)
            per_seed.append(res)
        row = {"nominal_rps": float(rate)}
        for key in ("offered_rps", "achieved_rps", "completion_rps",
                    "median_ms", "p99_ms", "mean_ms", "p999_ms"):
            row[key] = _mean([r[key] for r in per_seed])
        row["n"] = int(sum(r["n"] for r in per_seed))
        row["rejected"] = int(sum(r["rejected"] for r in per_seed))
        if row_telemetry:
            row["scale_events"] = int(sum(t["n_scale_events"]
                                          for t in row_telemetry))
            row["cold_path_arrivals"] = int(sum(t["cold_path_arrivals"]
                                                for t in row_telemetry))
            asc_runs.extend(row_telemetry)
        curve.append(row)
        pooled.append(lats)
    return _assemble_open(sc, duration, curve, pooled,
                          knee=knee_of_curve(curve, sc.slo_p99_ms),
                          rep_idx=knee_index_of_curve(curve, sc.slo_p99_ms),
                          asc_runs=asc_runs)


def _exec_storm(sc: Scenario, backend: str, duration_scale: float,
                smoke: bool) -> Dict[str, object]:
    k = min(8, sc.storm_functions) if smoke else sc.storm_functions
    deploy_ms: List[float] = []
    invoke_ms: List[float] = []
    total_ms: List[float] = []
    redeploy_ms: List[float] = []
    for seed in _seeds(sc, smoke):
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
        t0 = sim.now
        remaining = [k]

        def one(i):
            spec = _storm_spec(sc, i)
            yield from rt.deploy(spec)
            deploy_ms.append((sim.now - t0) * 1e3)
            rec = yield from rt.invoke(spec.name)
            invoke_ms.append(rec.e2e * 1e3)
            total_ms.append((sim.now - t0) * 1e3)
            remaining[0] -= 1
            if remaining[0] == 0:
                sim.stop()

        for i in range(k):
            sim.process(one(i))
        sim.run()
        assert remaining[0] == 0, "storm did not drain"
        # second wave: redeploy every storm function (config-update shape).
        # Plain backends pay the same cold start again; a snapshotting
        # backend (firecracker) restores from the snapshots the first wave
        # warmed — this is the storm's snapshot-restore-vs-full-boot signal
        remaining = [k]

        def again(i):
            t1 = sim.now
            yield from rt.deploy(_storm_spec(sc, i))
            redeploy_ms.append((sim.now - t1) * 1e3)
            remaining[0] -= 1
            if remaining[0] == 0:
                sim.stop()

        for i in range(k):
            sim.process(again(i))
        sim.run()
        assert remaining[0] == 0, "redeploy wave did not drain"
    # contention-free singles: a first deploy for the paper's
    # instance-init claim, a redeploy for the snapshot-restore claim
    sim = Simulator(seed=0)
    rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
    t0 = sim.now
    rt.deploy_blocking(FunctionSpec(name="solo"))
    single_deploy_ms = (sim.now - t0) * 1e3
    t0 = sim.now
    rt.deploy_blocking(FunctionSpec(name="solo"))
    single_redeploy_ms = (sim.now - t0) * 1e3
    d, t = LatencySummary.of(deploy_ms), LatencySummary.of(total_ms)
    return {
        "mode": "storm",
        "functions": k,
        "n": len(total_ms),
        "single_deploy_ms": single_deploy_ms,
        "single_redeploy_ms": single_redeploy_ms,
        "redeploy_speedup": single_deploy_ms / max(single_redeploy_ms, 1e-9),
        "deploy_median_ms": d.median_ms,
        "deploy_p99_ms": d.p99_ms,
        "redeploy_median_ms": LatencySummary.of(redeploy_ms).median_ms,
        "first_invoke_median_ms": LatencySummary.of(invoke_ms).median_ms,
        "median_ms": t.median_ms,       # deploy + first invoke, end to end
        "p99_ms": t.p99_ms,
        "hist": latency_histogram(total_ms),
    }


def _exec_mixed(sc: Scenario, backend: str, duration_scale: float,
                smoke: bool) -> Dict[str, object]:
    """Steady warm traffic plus a provisioning storm on the same worker:
    ``storm_functions`` deploy+invoke-train storms land mid-run while the
    warm mix keeps arriving, measuring how much the cold path inflates
    warm-path tail latency (and, with an autoscaler in the loop, how the
    controller reacts to the combined pressure)."""
    duration = max(0.5, sc.duration_s * duration_scale)
    storm_t = duration * 0.25       # warm window established first
    k = min(8, sc.storm_functions) if smoke else sc.storm_functions
    rates = sc.rates_for(backend, smoke=smoke)
    if not rates:
        raise ValueError(
            f"scenario {sc.name!r} has no rate grid for backend "
            f"{backend!r}; add rates[{backend!r}] or a '*' fallback")
    rate = float(rates[0])          # mixed mode runs one warm rate
    warm_names = set(sc.fn_names())
    per_seed: List[Dict[str, float]] = []
    asc_runs: List[Dict[str, object]] = []
    storm_deploy_ms: List[float] = []
    storm_total_ms: List[float] = []
    warm_lats_pooled: List[float] = []
    for seed in _seeds(sc, smoke):
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
        _deploy_mix(rt, sc.functions)
        asc = _make_autoscaler(sc, rt)
        t0 = sim.now
        storm_done_t: List[float] = []

        def one_storm(i, t0=t0, sim=sim, rt=rt, done=storm_done_t):
            # staggered FaaSNet-style storm: deploy + a short invoke train
            yield sim.timeout(storm_t + i * 0.002 - (sim.now - t0))
            spec = _storm_spec(sc, i)
            t_start = sim.now
            yield from rt.deploy(spec)
            storm_deploy_ms.append((sim.now - t_start) * 1e3)
            for _ in range(4):
                yield from rt.invoke(spec.name)
                yield sim.timeout(0.001)
            storm_total_ms.append((sim.now - t_start) * 1e3)
            done.append(sim.now - t0)

        for i in range(k):
            sim.process(one_storm(i))
        start_idx = len(rt.records)
        drive(rt, sc.load_spec(rate, duration), observer=asc)
        if asc is not None:
            asc_runs.append(asc.telemetry())
        warmup = sc.warmup_frac * duration
        warm = [r for r in rt.records[start_idx:] if r.fn in warm_names
                and r.t_arrival >= t0 + warmup]
        storm_end = t0 + (max(storm_done_t) if storm_done_t else duration)
        before = [r.e2e * 1e3 for r in warm
                  if r.t_arrival < t0 + storm_t]
        during = [r.e2e * 1e3 for r in warm
                  if t0 + storm_t <= r.t_arrival <= storm_end]
        lat = [r.e2e * 1e3 for r in warm]
        warm_lats_pooled.extend(lat)
        s = LatencySummary.of(lat)
        p99_before = percentile(before, 99)
        p99_during = percentile(during, 99)
        # short smoke runs can leave the pre-storm warm window [warmup,
        # storm_t) empty: the percentiles come back NaN (or zero), and an
        # unguarded division would ship a NaN that poisons compare.py
        # baselines — flag the seed instead
        warm_ok = (math.isfinite(p99_before) and p99_before > 0
                   and math.isfinite(p99_during))
        per_seed.append({
            "n": s.n, "median_ms": s.median_ms, "p99_ms": s.p99_ms,
            "warm_median_before_ms": percentile(before, 50),
            "warm_median_during_ms": percentile(during, 50),
            "warm_p99_before_ms": p99_before,
            "warm_p99_during_ms": p99_during,
            "warm_p99_inflation": (p99_during / p99_before) if warm_ok
            else float("nan"),
            "insufficient_warm_samples": not warm_ok,
        })
    out: Dict[str, object] = {
        "mode": "mixed",
        "duration_s": duration,
        "storm_t_s": storm_t,
        "storm_functions": k,
        "warm_rps": rate,
        "arrival_kind": sc.arrival.kind,
        "n": int(sum(r["n"] for r in per_seed)),
        "storm_deploy_median_ms": LatencySummary.of(storm_deploy_ms).median_ms,
        "storm_total_median_ms": LatencySummary.of(storm_total_ms).median_ms,
        "hist": latency_histogram(warm_lats_pooled),
    }
    for key in ("median_ms", "p99_ms"):
        out[key] = _mean([r[key] for r in per_seed])
    for key in ("warm_median_before_ms", "warm_median_during_ms",
                "warm_p99_before_ms", "warm_p99_during_ms",
                "warm_p99_inflation"):
        out[key] = _finite_mean([r[key] for r in per_seed])
    out["insufficient_warm_samples"] = int(sum(
        r["insufficient_warm_samples"] for r in per_seed))
    if asc_runs:
        out["autoscaler"] = _pool_autoscaler(asc_runs)
    return out


def _fleet_warm_targets(sc: Scenario, spec: FleetSpec) -> Dict[str, object]:
    """Per-function worker subsets for the warm mix.

    ``spread="all"`` puts every function everywhere (None = all
    workers).  ``spread="zipf"`` gives the rank-r function a contiguous
    worker block sized by its popularity share (min 2 workers for
    redundancy), rotated per rank so the blocks interleave instead of
    piling onto worker 0."""
    if spec.spread == "all":
        return {prof.name: None for prof in sc.functions}
    n = spec.n_workers
    w_max = max(p.weight for p in sc.functions)
    out: Dict[str, object] = {}
    for r, prof in enumerate(sc.functions):
        k = max(2, min(n, int(round(n * prof.weight / w_max))))
        start = (r * 7) % n
        out[prof.name] = [(start + j) % n for j in range(k)]
    return out


def _fleet_run(sc: Scenario, backend: str, seed: int, placement: str,
               distribution: str, rate: float, duration: float,
               spec: FleetSpec,
               targets: Dict[str, object]) -> Dict[str, object]:
    """One (placement, distribution, seed) fleet run: deploy the warm
    mix, drive gateway-routed traffic, optionally land a provisioning
    storm mid-run (completing it past the drive window if needed)."""
    sim = Simulator(seed=seed)
    cluster = Cluster(
        sim, spec.n_workers, backend=backend, n_cores=sc.n_cores,
        placement=placement, distribution=distribution,
        image_mb=spec.image_mb, origin_gbps=spec.origin_gbps,
        peer_gbps=spec.peer_gbps, fanout=spec.fanout,
        spill_load=spec.spill_load,
        scale_policy=sc.autoscaler.build if sc.autoscaler else None)
    for prof in sc.functions:
        work = prof.work_us
        if prof.heavy_tail_alpha is not None:
            work = heavy_tailed_work(sim.rng, prof.work_us,
                                     alpha=prof.heavy_tail_alpha)
        cluster.deploy_blocking(
            FunctionSpec(name=prof.name, work_us=work,
                         payload_bytes=prof.payload_bytes,
                         response_bytes=prof.response_bytes,
                         scale=prof.scale, max_cores=prof.max_cores),
            workers=targets[prof.name])
    t0 = sim.now
    storm_t = spec.storm_t_frac * duration
    storm_proc = None
    if spec.storm_replicas:
        storm_fn = FunctionSpec(name="storm-fn", max_cores=2)

        def launch():
            yield sim.timeout(storm_t)
            yield from cluster.scale_out(storm_fn, spec.storm_replicas)

        storm_proc = sim.process(launch())
    res = drive(cluster, sc.load_spec(rate, duration))
    if storm_proc is not None and not storm_proc.done:
        # a slow (naive) distribution can outlast the drive window: run
        # the shared heap on until the storm lands so time-to-full is
        # always measured, never truncated
        storm_proc.completion.callbacks.append(lambda _v: sim.stop())
        sim.run()
        assert storm_proc.done, "provisioning storm did not converge"
    out: Dict[str, object] = {
        "n": res["n"], "median_ms": res["median_ms"],
        "p99_ms": res["p99_ms"], "rejected": res["rejected"],
        "latencies_ms": res["latencies_ms"],
        "workers": res["fleet"]["workers"],
        "expansions": len(res["fleet"]["expansions"]),
    }
    warmup = sc.warmup_frac * duration
    if spec.storm_replicas:
        storm = cluster.storms[-1]
        t_end = storm["t_start_s"] + storm["time_to_full_s"]
        warm_names = set(sc.fn_names())
        warm = [r for w in cluster.workers for r in w.runtime.records
                if r.fn in warm_names and r.t_arrival >= t0 + warmup]
        before = [r.e2e * 1e3 for r in warm if r.t_arrival < t0 + storm_t]
        during = [r.e2e * 1e3 for r in warm
                  if t0 + storm_t <= r.t_arrival <= t_end]
        p99_before = percentile(before, 99)
        p99_during = percentile(during, 99)
        warm_ok = (math.isfinite(p99_before) and p99_before > 0
                   and math.isfinite(p99_during))
        out.update({
            "time_to_full_s": storm["time_to_full_s"],
            "storm": storm,
            "warm_p99_before_ms": p99_before,
            "warm_p99_during_ms": p99_during,
            "warm_p99_inflation": (p99_during / p99_before) if warm_ok
            else float("nan"),
            "insufficient_warm_samples": not warm_ok,
        })
        by_wid = {d["worker"]: d for d in storm["workers"]}
        for blk in out["workers"]:
            sd = by_wid.get(blk["worker"])
            if sd is not None:
                blk["storm_replicas"] = sd["replicas"]
                blk["storm_pulled"] = sd["pulled"]
                blk["storm_t_ready_s"] = sd["t_ready_s"]
    if sc.autoscaler is not None:
        tele = [w.autoscaler.telemetry() for w in cluster.workers]
        out["autoscaler_runs"] = tele
        for blk, t in zip(out["workers"], tele):
            rx = t["reactions_ms"]
            blk["reaction_p50_ms"] = (round(percentile(rx, 50), 3)
                                      if rx else None)
            blk["n_scale_events"] = t["n_scale_events"]
    return out


def _exec_fleet(sc: Scenario, backend: str, duration_scale: float,
                smoke: bool) -> Dict[str, object]:
    """Fleet mode: N workers behind a gateway, per-variant runs over the
    (placement x distribution) grid from the scenario's FleetSpec.

    ``rates[backend][0]`` is the per-worker warm rate; the gateway
    admits ``rate * n_workers``.  The first (primary) variant provides
    the scenario's headline latency stats; when the spec compares tree
    vs naive distribution the fleet block carries
    ``tree_provisioning_speedup`` (naive/tree time-to-full-capacity)."""
    spec = sc.fleet or FleetSpec()
    duration = max(0.5, sc.duration_s * duration_scale)
    rates = sc.rates_for(backend, smoke=smoke)
    if not rates:
        raise ValueError(
            f"scenario {sc.name!r} has no per-worker rate for backend "
            f"{backend!r}; add rates[{backend!r}] or a '*' fallback")
    per_worker_rps = float(rates[0])
    rate = per_worker_rps * spec.n_workers
    targets = _fleet_warm_targets(sc, spec)
    variants: List[Dict[str, object]] = []
    primary_lats: List[float] = []
    for placement in spec.placements():
        for distribution in spec.distributions():
            per_seed: List[Dict[str, object]] = []
            for seed in _seeds(sc, smoke):
                per_seed.append(_fleet_run(sc, backend, seed, placement,
                                           distribution, rate, duration,
                                           spec, targets))
            first = per_seed[0]
            blk: Dict[str, object] = {
                "placement": placement,
                "distribution": distribution,
                "n": int(sum(r["n"] for r in per_seed)),
                "median_ms": _mean([r["median_ms"] for r in per_seed]),
                "p99_ms": _mean([r["p99_ms"] for r in per_seed]),
                "rejected": int(sum(r["rejected"] for r in per_seed)),
                "expansions": int(sum(r["expansions"] for r in per_seed)),
                "workers": first["workers"],    # per-worker telemetry
            }
            if spec.storm_replicas:
                blk["time_to_full_s"] = _mean(
                    [r["time_to_full_s"] for r in per_seed])
                storm = dict(first["storm"])
                storm["pulls"] = storm["pulls"][:2 * spec.n_workers]
                blk["storm"] = storm
                for key in ("warm_p99_before_ms", "warm_p99_during_ms",
                            "warm_p99_inflation"):
                    blk[key] = _finite_mean([r[key] for r in per_seed])
                blk["insufficient_warm_samples"] = int(sum(
                    r["insufficient_warm_samples"] for r in per_seed))
            asc_runs = [t for r in per_seed
                        for t in r.get("autoscaler_runs", ())]
            if asc_runs:
                blk["autoscaler"] = _pool_autoscaler(asc_runs)
            if not variants:        # primary variant feeds the histogram
                primary_lats = [x for r in per_seed
                                for x in r["latencies_ms"]]
            variants.append(blk)
    primary = variants[0]
    fleet: Dict[str, object] = {
        "n_workers": spec.n_workers,
        "placement": spec.placement,
        "distribution": spec.distribution,
        "spread": spec.spread,
        "image_mb": spec.image_mb,
        "storm_replicas": spec.storm_replicas,
        "variants": variants,
    }
    if spec.storm_replicas:
        by_dist = {v["distribution"]: v for v in variants
                   if v["placement"] == spec.placement
                   and "time_to_full_s" in v}
        if "tree" in by_dist and "naive" in by_dist:
            fleet["tree_provisioning_speedup"] = round(
                by_dist["naive"]["time_to_full_s"]
                / max(by_dist["tree"]["time_to_full_s"], 1e-9), 2)
    out: Dict[str, object] = {
        "mode": "fleet",
        "duration_s": duration,
        "arrival_kind": sc.arrival.kind,
        "n_workers": spec.n_workers,
        "warm_rps_per_worker": per_worker_rps,
        "warm_rps": rate,
        "n": primary["n"],
        "median_ms": primary["median_ms"],
        "p99_ms": primary["p99_ms"],
        "hist": latency_histogram(primary_lats),
        "fleet": fleet,
    }
    for key in ("warm_p99_before_ms", "warm_p99_during_ms",
                "warm_p99_inflation", "insufficient_warm_samples",
                "time_to_full_s"):
        if key in primary:
            out[key] = primary[key]
    if "autoscaler" in primary:
        out["autoscaler"] = primary["autoscaler"]
    return out


def _pool_chain(blocks: List[dict]) -> Dict[str, object]:
    """Reduce per-seed chain blocks into one: counters summed, latency
    stats seed-averaged, per-hop-depth rows matched by depth."""
    out: Dict[str, object] = {
        "n_roots": int(sum(b["n_roots"] for b in blocks)),
        "roots_completed": int(sum(b["roots_completed"] for b in blocks)),
        "rejected_hops": int(sum(b["rejected_hops"] for b in blocks)),
        "fused_members": int(sum(b["fused_members"] for b in blocks)),
    }
    for key in ("root_median_ms", "root_p99_ms", "root_mean_ms",
                "hop_tax_mean_ms"):
        out[key] = round(_finite_mean([b[key] for b in blocks]), 6)
    hops: List[dict] = []
    for d in sorted({r["hop"] for b in blocks for r in b["hops"]}):
        rows = [r for b in blocks for r in b["hops"] if r["hop"] == d]
        hops.append({
            "hop": d,
            "n": int(sum(r["n"] for r in rows)),
            **{k: round(_finite_mean([r[k] for r in rows]), 6)
               for k in ("median_ms", "p99_ms", "mean_ms", "tax_mean_ms")},
        })
    out["hops"] = hops
    return out


def _chain_run(sc: Scenario, backend: str, seed: int, rate: float,
               duration: float, fusion) -> Dict[str, object]:
    """One fresh-runtime chain run; records the core pool's busy time so
    fused and unfused runs can compare worker-side CPU cost."""
    sim = Simulator(seed=seed)
    rt = FaasdRuntime(sim, backend=backend, n_cores=sc.n_cores)
    _deploy_mix(rt, sc.functions)
    res = drive(rt, sc.load_spec(rate, duration, fusion=fusion))
    res["pool_busy_s"] = float(rt.cores.busy_time)
    return res


def _exec_chain(sc: Scenario, backend: str, duration_scale: float,
                smoke: bool) -> Dict[str, object]:
    """Chain mode: each admitted root arrival expands into its downstream
    hop tree (FunctionProfile.edges), so per-hop latency breakdowns and
    the per-hop platform tax land in the artifact.  When the scenario
    carries a FusionPlan that applies to this backend, a same-seed fused
    run rides along: fused hops skip gateway + netstack and execute
    inside the caller's sandbox, and the result block carries the
    fused-vs-unfused P99 and pool-efficiency comparison."""
    duration = max(0.5, sc.duration_s * duration_scale)
    rates = sc.rates_for(backend, smoke=smoke)
    if not rates:
        raise ValueError(
            f"scenario {sc.name!r} has no rate grid for backend "
            f"{backend!r}; add rates[{backend!r}] or a '*' fallback")
    rate = float(rates[0])
    per_seed: List[Dict[str, object]] = []
    fused_seed: List[Dict[str, object]] = []
    pooled: List[float] = []
    run_fused = sc.fusion is not None and sc.fusion.applies_to(backend)
    for seed in _seeds(sc, smoke):
        res = _chain_run(sc, backend, seed, rate, duration, fusion=None)
        pooled.extend(res["latencies_ms"])
        per_seed.append(res)
        if run_fused:
            fused_seed.append(_chain_run(sc, backend, seed, rate, duration,
                                         fusion=sc.fusion))
    chain = _pool_chain([r["chain"] for r in per_seed])
    out: Dict[str, object] = {
        "mode": "chain",
        "duration_s": duration,
        "rate_rps": rate,
        "arrival_kind": sc.arrival.kind,
        "n": int(sum(r["n"] for r in per_seed)),
        "median_ms": _mean([r["median_ms"] for r in per_seed]),
        "p99_ms": _mean([r["p99_ms"] for r in per_seed]),
        "mean_ms": _mean([r["mean_ms"] for r in per_seed]),
        "rejected": int(sum(r["rejected"] for r in per_seed)),
        "chain": chain,
        "hist": latency_histogram(pooled),
    }
    if fused_seed:
        fchain = _pool_chain([r["chain"] for r in fused_seed])
        busy_u = sum(r["pool_busy_s"] for r in per_seed)
        busy_f = sum(r["pool_busy_s"] for r in fused_seed)
        out["fusion"] = {
            "edges": [list(e) for e in sc.fusion.edges],
            "chain": fchain,
            "p99_improvement": round(
                chain["root_p99_ms"] / max(fchain["root_p99_ms"], 1e-9), 4),
            "median_improvement": round(
                chain["root_median_ms"]
                / max(fchain["root_median_ms"], 1e-9), 4),
            "pool_busy_unfused_s": round(busy_u, 6),
            "pool_busy_fused_s": round(busy_f, 6),
            "pool_efficiency": round(busy_u / max(busy_f, 1e-9), 4),
        }
    return out


_MODES = {"closed": _exec_closed, "open": _exec_open, "storm": _exec_storm,
          "mixed": _exec_mixed, "fleet": _exec_fleet, "chain": _exec_chain}


def _run_backend(item: Tuple[Scenario, str, float, bool]):
    """Worker entry point: one (scenario, backend) cell of the matrix."""
    sc, backend, duration_scale, smoke = item
    # simlint: allow[wall-clock] measures host elapsed time of the worker
    t0 = time.time()
    try:
        res = _MODES[sc.mode](sc, backend, duration_scale, smoke)
        # simlint: allow[wall-clock] elapsed_s reports host wall time
        res["elapsed_s"] = round(time.time() - t0, 2)
        return sc.name, backend, res, None
    except Exception:
        return sc.name, backend, None, traceback.format_exc()


# ---------------------------------------------------------------------------
# Paper-claim reductions.  Every builder works on the scenario's
# (baseline, treatment) pair — no backend names are hardcoded, so claims
# survive arbitrary backend matrices as long as the pair is part of them.


def _fig5_claims(base: dict, treat: dict) -> Dict[str, dict]:
    def red(key):
        return 100.0 * (1.0 - treat[key] / base[key])

    measured = {
        "e2e_median": red("median_ms"),
        "e2e_p99": red("p99_ms"),
        "exec_median": red("exec_median_ms"),
        "exec_p99": red("exec_p99_ms"),
    }
    return {f"{k}_reduction_pct": {"measured": round(v, 2),
                                   "paper": PAPER_FIG5[k],
                                   "delta": round(v - PAPER_FIG5[k], 2)}
            for k, v in measured.items()}


def _fig6_claims(base: dict, treat: dict) -> Dict[str, dict]:
    b_knee, t_knee = base["knee_rps"], treat["knee_rps"]
    ratio = t_knee / max(1.0, b_knee)
    claims = {
        "baseline_knee_rps": {"measured": b_knee},
        "treatment_knee_rps": {"measured": t_knee},
        "throughput_ratio": {
            "measured": round(ratio, 2), "paper": PAPER_FIG6["throughput_ratio"],
            "delta": round(ratio - PAPER_FIG6["throughput_ratio"], 2)},
    }
    # the baseline's knee row is tracked by index ("knee_row"), never by
    # re-matching the knee rate with float equality: search-generated
    # rates are not grid-aligned, and pooled multi-seed knees match no row
    b_at = (base["curve"][int(base["knee_row"])]
            if b_knee > 0 and base.get("knee_row") is not None else None)
    # only full-resolution rows may represent the treatment: a search
    # curve also holds short low-res bracket probes whose tails are
    # under-sampled (grid rows carry no "phase" and all qualify)
    t_curve = [r for r in treat["curve"] if r.get("phase") != "bracket"] \
        or treat["curve"]
    if b_at and t_curve and b_knee > 0:
        # latency comparison at ~1.3x the baseline's knee, as in the
        # paper — taken at the nearest measured treatment rate, which
        # the claim records since neither grids nor search probes are
        # guaranteed to have sampled that exact load
        target = b_knee * 1.3
        t_at = min(t_curve, key=lambda r: abs(r["nominal_rps"] - target))
        claims["latency_compare_rps"] = {
            "measured": round(float(t_at["nominal_rps"]), 1),
            "target": round(target, 1)}
        for key, short in (("median_ms", "median_speedup"),
                           ("p99_ms", "p99_speedup")):
            x = b_at[key] / t_at[key]
            claims[short] = {"measured": round(x, 2),
                             "paper": PAPER_FIG6[short],
                             "delta": round(x - PAPER_FIG6[short], 2)}
    return claims


def _coldstart_claims(base: dict, treat: dict) -> Dict[str, dict]:
    ti, bi = treat["single_deploy_ms"], base["single_deploy_ms"]
    return {
        "treatment_init_ms": {"measured": round(ti, 3),
                              "paper": PAPER_COLDSTART_JUNCTION_MS,
                              "delta": round(ti - PAPER_COLDSTART_JUNCTION_MS, 3)},
        "baseline_coldstart_ms": {"measured": round(bi, 3)},
        "coldstart_ratio": {"measured": round(bi / ti, 1)},
        "storm_speedup": {
            "measured": round(base["median_ms"] / treat["median_ms"], 1)},
    }


def _autoscale_claims(base: dict, treat: dict) -> Dict[str, dict]:
    """Scale-up reaction time (pressure onset -> new capacity ready): the
    control-plane metric the cold-start asymmetry buys (FaaSNet's
    provisioning-storm regime)."""
    b, t = base["autoscaler"], treat["autoscaler"]
    ratio = b["reaction_p50_ms"] / max(t["reaction_p50_ms"], 1e-9)
    return {
        "baseline_reaction_p50_ms": {"measured": round(b["reaction_p50_ms"], 3)},
        "treatment_reaction_p50_ms": {"measured": round(t["reaction_p50_ms"], 3)},
        "baseline_reaction_p99_ms": {"measured": round(b["reaction_p99_ms"], 3)},
        "treatment_reaction_p99_ms": {"measured": round(t["reaction_p99_ms"], 3)},
        "scaleup_reaction_ratio": {"measured": round(ratio, 1)},
        "baseline_cold_path_arrivals": {
            "measured": b["cold_path_arrivals"]},
        "treatment_cold_path_arrivals": {
            "measured": t["cold_path_arrivals"]},
    }


def _interference_claims(base: dict, treat: dict) -> Dict[str, dict]:
    """Warm-path P99 inflation while a provisioning storm shares the
    worker (cold/warm path coupling)."""
    b_inf, t_inf = base["warm_p99_inflation"], treat["warm_p99_inflation"]
    return {
        "baseline_warm_p99_inflation": {"measured": round(b_inf, 3)},
        "treatment_warm_p99_inflation": {"measured": round(t_inf, 3)},
        "interference_reduction": {"measured": round(b_inf / max(t_inf, 1e-9), 3)},
        "baseline_storm_total_ms": {
            "measured": round(base["storm_total_median_ms"], 3)},
        "treatment_storm_total_ms": {
            "measured": round(treat["storm_total_median_ms"], 3)},
    }


def _fleet_claims(base: dict, treat: dict) -> Dict[str, dict]:
    """FaaSNet-regime provisioning claim: tree distribution's
    time-to-full-capacity advantage over naive registry pulls during a
    fleet-wide storm, while warm-path P99 stays flat.  The headline
    speedup is the min over the claims pair — the gate holds for the
    *worst* of the two backends, not a favorable one."""
    b_fl, t_fl = base["fleet"], treat["fleet"]
    b_spd = b_fl.get("tree_provisioning_speedup", float("nan"))
    t_spd = t_fl.get("tree_provisioning_speedup", float("nan"))
    headline = min(b_spd, t_spd)

    def ttf(fl: dict, dist: str) -> float:
        v = next((v for v in fl["variants"]
                  if v["distribution"] == dist
                  and v["placement"] == fl["placement"]), None)
        return v.get("time_to_full_s", float("nan")) if v else float("nan")

    inflation = _finite_mean([base.get("warm_p99_inflation", float("nan")),
                              treat.get("warm_p99_inflation", float("nan"))])
    return {
        "fleet_tree_provisioning_speedup": {"measured": round(headline, 2)},
        "baseline_tree_speedup": {"measured": round(b_spd, 2)},
        "treatment_tree_speedup": {"measured": round(t_spd, 2)},
        "baseline_tree_time_to_full_s": {
            "measured": round(ttf(b_fl, "tree"), 4)},
        "baseline_naive_time_to_full_s": {
            "measured": round(ttf(b_fl, "naive"), 4)},
        "treatment_tree_time_to_full_s": {
            "measured": round(ttf(t_fl, "tree"), 4)},
        "treatment_naive_time_to_full_s": {
            "measured": round(ttf(t_fl, "naive"), 4)},
        "fleet_warm_p99_inflation": {"measured": round(inflation, 3)},
    }


def _chain_claims(base: dict, treat: dict) -> Dict[str, dict]:
    """Per-hop platform tax (hop latency minus exec span): the chain-tax
    claim is that the treatment's kernel-bypass datapath pays a fraction
    of the baseline's per-hop overhead, so deep pipelines compound the
    advantage."""
    b, t = base["chain"], treat["chain"]
    b_tax, t_tax = b["hop_tax_mean_ms"], t["hop_tax_mean_ms"]
    return {
        "baseline_hop_tax_ms": {"measured": round(b_tax, 4)},
        "treatment_hop_tax_ms": {"measured": round(t_tax, 4)},
        "chain_hop_tax_ratio": {"measured": round(b_tax / max(t_tax, 1e-9), 3)},
        "baseline_root_median_ms": {"measured": round(b["root_median_ms"], 4)},
        "treatment_root_median_ms": {"measured": round(t["root_median_ms"], 4)},
        "baseline_root_p99_ms": {"measured": round(b["root_p99_ms"], 4)},
        "treatment_root_p99_ms": {"measured": round(t["root_p99_ms"], 4)},
    }


def _chain_fusion_claims(base: dict, treat: dict) -> Dict[str, dict]:
    """Platform-side fusion claim: co-locating chain edges into the
    caller's sandbox removes per-hop gateway + netstack cost.  The
    headline improvement is measured on the *baseline* (containerd-class)
    backend, where per-hop overhead — and therefore the win — is
    largest."""
    b_f, t_f = base["fusion"], treat["fusion"]
    return {
        "chain_fusion_p99_improvement": {
            "measured": round(b_f["p99_improvement"], 3)},
        "treatment_fusion_p99_improvement": {
            "measured": round(t_f["p99_improvement"], 3)},
        "chain_fusion_pool_efficiency": {
            "measured": round(b_f["pool_efficiency"], 3)},
        "baseline_unfused_root_p99_ms": {
            "measured": round(base["chain"]["root_p99_ms"], 4)},
        "baseline_fused_root_p99_ms": {
            "measured": round(b_f["chain"]["root_p99_ms"], 4)},
        "baseline_median_improvement": {
            "measured": round(b_f["median_improvement"], 3)},
    }


_CLAIMS = {"fig5": _fig5_claims, "fig6": _fig6_claims,
           "coldstart": _coldstart_claims, "autoscale": _autoscale_claims,
           "interference": _interference_claims, "fleet": _fleet_claims,
           "chain": _chain_claims, "chain_fusion": _chain_fusion_claims}


def _claim_metric_rows(sc: Scenario, backends: Dict[str, dict],
                       claims: Dict[str, dict]) -> List[dict]:
    """Flat rows; names derive from the claims pair, so the default
    containerd/junctiond pair keeps the CSV metric names stable — with
    one deliberate rename: ``coldstart_junction_init`` is now
    ``coldstart_junctiond_init`` (pair-derived), so pre-rename artifacts
    need regenerating before they can serve as compare.py baselines."""
    base_name, treat_name = sc.claims_pair
    base, treat = backends[base_name], backends[treat_name]
    rows: List[dict] = []
    if sc.claims_kind == "fig5":
        rows += [
            metric_row(f"fig5_{base_name}_median",
                       base["median_ms"] * 1e3, "us e2e"),
            metric_row(f"fig5_{treat_name}_median",
                       treat["median_ms"] * 1e3, "us e2e"),
        ]
        for name, key in (("fig5_median_reduction", "e2e_median"),
                          ("fig5_p99_reduction", "e2e_p99"),
                          ("fig5_exec_median_reduction", "exec_median"),
                          ("fig5_exec_p99_reduction", "exec_p99")):
            cl = claims[f"{key}_reduction_pct"]
            rows.append(metric_row(name, cl["measured"],
                                   f"% vs paper {cl['paper']}%"))
    elif sc.claims_kind == "fig6":
        rows += [
            metric_row(f"fig6_{base_name}_sustainable_rps",
                       claims["baseline_knee_rps"]["measured"],
                       f"rps at p99<={sc.slo_p99_ms:.0f}ms"),
            metric_row(f"fig6_{treat_name}_sustainable_rps",
                       claims["treatment_knee_rps"]["measured"],
                       f"rps at p99<={sc.slo_p99_ms:.0f}ms"),
            metric_row("fig6_throughput_ratio",
                       claims["throughput_ratio"]["measured"], "x (paper ~10x)"),
        ]
        if "median_speedup" in claims:
            rows += [
                metric_row("fig6_median_speedup_at_load",
                           claims["median_speedup"]["measured"], "x (paper ~2x)"),
                metric_row("fig6_p99_speedup_at_load",
                           claims["p99_speedup"]["measured"], "x (paper ~3.5x)"),
            ]
    elif sc.claims_kind == "coldstart":
        rows += [
            metric_row(f"coldstart_{treat_name}_init",
                       claims["treatment_init_ms"]["measured"] * 1e3,
                       "us (paper 3.4ms)"),
            metric_row(f"coldstart_{base_name}",
                       claims["baseline_coldstart_ms"]["measured"] * 1e3, "us"),
            metric_row("coldstart_ratio",
                       claims["coldstart_ratio"]["measured"],
                       f"x {base_name}/{treat_name}"),
            metric_row("coldstart_storm_speedup",
                       claims["storm_speedup"]["measured"],
                       f"x, {treat['functions']} concurrent deploys"),
        ]
    elif sc.claims_kind == "autoscale":
        rows += [
            metric_row(f"autoscale_{base_name}_reaction",
                       claims["baseline_reaction_p50_ms"]["measured"],
                       "ms scale-up reaction p50"),
            metric_row(f"autoscale_{treat_name}_reaction",
                       claims["treatment_reaction_p50_ms"]["measured"],
                       "ms scale-up reaction p50"),
            metric_row("autoscale_reaction_ratio",
                       claims["scaleup_reaction_ratio"]["measured"],
                       f"x {base_name}/{treat_name}"),
        ]
    elif sc.claims_kind == "interference":
        rows += [
            metric_row(f"mixed_{base_name}_warm_p99_inflation",
                       claims["baseline_warm_p99_inflation"]["measured"],
                       "x warm p99 during/before storm"),
            metric_row(f"mixed_{treat_name}_warm_p99_inflation",
                       claims["treatment_warm_p99_inflation"]["measured"],
                       "x warm p99 during/before storm"),
            metric_row("mixed_interference_reduction",
                       claims["interference_reduction"]["measured"],
                       f"x {base_name}/{treat_name} p99 inflation"),
        ]
    elif sc.claims_kind == "chain":
        rows += [
            metric_row(f"chain_{base_name}_hop_tax",
                       claims["baseline_hop_tax_ms"]["measured"] * 1e3,
                       "us per-hop platform overhead"),
            metric_row(f"chain_{treat_name}_hop_tax",
                       claims["treatment_hop_tax_ms"]["measured"] * 1e3,
                       "us per-hop platform overhead"),
            metric_row("chain_hop_tax_ratio",
                       claims["chain_hop_tax_ratio"]["measured"],
                       f"x {base_name}/{treat_name} per-hop tax"),
        ]
    elif sc.claims_kind == "chain_fusion":
        rows += [
            metric_row("chain_fusion_p99_improvement",
                       claims["chain_fusion_p99_improvement"]["measured"],
                       f"x unfused/fused root p99 ({base_name})"),
            metric_row("chain_fusion_pool_efficiency",
                       claims["chain_fusion_pool_efficiency"]["measured"],
                       f"x unfused/fused pool busy-time ({base_name})"),
            metric_row(f"chain_fusion_{treat_name}_p99_improvement",
                       claims["treatment_fusion_p99_improvement"]["measured"],
                       "x unfused/fused root p99"),
        ]
    elif sc.claims_kind == "fleet":
        rows += [
            metric_row("fleet_tree_provisioning_speedup",
                       claims["fleet_tree_provisioning_speedup"]["measured"],
                       f"x naive/tree time-to-full, min over "
                       f"({base_name}, {treat_name})"),
            metric_row(f"fleet_{base_name}_tree_speedup",
                       claims["baseline_tree_speedup"]["measured"],
                       "x naive/tree time-to-full-capacity"),
            metric_row(f"fleet_{treat_name}_tree_speedup",
                       claims["treatment_tree_speedup"]["measured"],
                       "x naive/tree time-to-full-capacity"),
            metric_row("fleet_warm_p99_inflation",
                       claims["fleet_warm_p99_inflation"]["measured"],
                       "x warm p99 during/before the storm (tree, "
                       "pair mean)"),
        ]
    return rows


# ---------------------------------------------------------------------------


class ExperimentRunner:
    """Runs scenarios across the backend matrix, serially or in worker
    processes, and reduces results into one bench artifact."""

    def __init__(self, duration_scale: float = 1.0, smoke: bool = False,
                 workers: int = 0, verbose: bool = False):
        self.duration_scale = duration_scale
        self.smoke = smoke
        self.workers = workers
        self.verbose = verbose

    # -- execution --------------------------------------------------------
    def _execute(self, items: List[Tuple[Scenario, str, float, bool]]):
        if self.workers and self.workers > 1 and len(items) > 1:
            with multiprocessing.Pool(min(self.workers, len(items))) as pool:
                return pool.map(_run_backend, items)
        return [_run_backend(it) for it in items]

    def run_scenario(self, sc: Scenario) -> Dict[str, object]:
        doc = self.run_suite([sc], suite="adhoc")
        return doc["scenarios"][0]

    def run_suite(self, scenarios: Sequence[Scenario],
                  suite: str = "scenarios") -> Dict[str, object]:
        items = [(sc, backend, self.duration_scale, self.smoke)
                 for sc in scenarios for backend in sc.backends]
        # simlint: allow[wall-clock] suite wall_s measures host elapsed time
        t0 = time.time()
        raw = self._execute(items)
        by_name: Dict[str, Dict[str, dict]] = {}
        failures: List[Dict[str, str]] = []
        for name, backend, res, err in raw:
            if err is not None:
                failures.append({"scenario": name, "backend": backend,
                                 "error": err})
                if self.verbose:
                    print(f"  !! {name}/{backend} FAILED:\n{err}")
            else:
                by_name.setdefault(name, {})[backend] = res

        out_scenarios: List[Dict[str, object]] = []
        metrics: List[dict] = []
        for sc in scenarios:
            backends = by_name.get(sc.name, {})
            entry: Dict[str, object] = {
                "name": sc.name,
                "mode": sc.mode,
                "description": sc.description,
                "arrival_kind": sc.arrival.kind,
                "tags": list(sc.tags),
                "backend_set": sorted(sc.backends),
                "claims_pair": list(sc.claims_pair),
                "backends": backends,
            }
            if sc.autoscaler is not None:
                entry["autoscaler_spec"] = dataclasses.asdict(sc.autoscaler)
            pair_ok = all(b in backends for b in sc.claims_pair)
            if sc.claims_kind and pair_ok:
                base, treat = sc.claims_pair
                claims = _CLAIMS[sc.claims_kind](backends[base],
                                                 backends[treat])
                entry["claims"] = claims
                metrics.extend(_claim_metric_rows(sc, backends, claims))
            for backend, res in backends.items():
                if "median_ms" in res:
                    metrics.append(metric_row(
                        f"scn_{sc.name}_{backend}_median",
                        res["median_ms"] * 1e3, f"us ({sc.mode})"))
                    metrics.append(metric_row(
                        f"scn_{sc.name}_{backend}_p99",
                        res["p99_ms"] * 1e3, f"us ({sc.mode})"))
                if res.get("mode") == "open" and res.get("knee_rps"):
                    # knee-0 results (SLO infeasible at this duration,
                    # e.g. deep MMPP bursts in smoke windows) emit no row:
                    # a later nonzero knee would otherwise diff against a
                    # meaningless zero baseline, and a knee that *drops*
                    # to 0 shows up as a missing-metric regression anyway
                    metrics.append(metric_row(
                        f"scn_{sc.name}_{backend}_knee",
                        res["knee_rps"],
                        f"rps at p99<={sc.slo_p99_ms:g}ms"))
                if "autoscaler" in res:
                    metrics.append(metric_row(
                        f"scn_{sc.name}_{backend}_scaleup_reaction",
                        res["autoscaler"]["reaction_p50_ms"],
                        "ms pressure->capacity-ready p50"))
                if "redeploy_speedup" in res:
                    metrics.append(metric_row(
                        f"scn_{sc.name}_{backend}_redeploy_speedup",
                        res["redeploy_speedup"],
                        "x first-deploy/redeploy (snapshot restore)"))
                if res.get("mode") == "fleet":
                    fl = res["fleet"]
                    if "tree_provisioning_speedup" in fl:
                        metrics.append(metric_row(
                            f"scn_{sc.name}_{backend}_tree_provisioning"
                            f"_speedup",
                            fl["tree_provisioning_speedup"],
                            "x naive/tree storm time-to-full"))
                    for v in fl["variants"]:
                        primary = (v["placement"] == fl["placement"]
                                   and v["distribution"]
                                   == fl["distribution"])
                        # label each variant row by the axis it varies
                        label = (v["placement"]
                                 if v["placement"] != fl["placement"]
                                 else v["distribution"])
                        if "time_to_full_s" in v:
                            metrics.append(metric_row(
                                f"scn_{sc.name}_{backend}_"
                                f"{v['distribution']}_time_to_full",
                                v["time_to_full_s"] * 1e3,
                                "ms storm time to full capacity"))
                        if not primary and "time_to_full_s" not in v:
                            metrics.append(metric_row(
                                f"scn_{sc.name}_{backend}_{label}_p99",
                                v["p99_ms"] * 1e3, "us (fleet variant)"))
            probes = sum(res["search"]["n_probes"]
                         for res in backends.values() if "search" in res)
            if probes:
                # one row per scenario, not per backend: a benign +-1
                # probe shift on a 2-probe cell would trip compare.py's
                # relative threshold, while a systemic sampling-cost
                # change still moves the scenario total past it
                metrics.append(metric_row(
                    f"scn_{sc.name}_search_probes", probes,
                    "open-loop runs spent locating knees (all backends)"))
            out_scenarios.append(entry)

        meta = {
            "smoke": self.smoke,
            "workers": self.workers,
            # simlint: allow[wall-clock] wall_s reports host wall time
            "wall_s": round(time.time() - t0, 2),
            "n_scenarios": len(scenarios),
            "backends": sorted({b for sc in scenarios for b in sc.backends}),
        }
        return build_artifact(suite, out_scenarios, metrics, failures,
                              duration_scale=self.duration_scale, meta=meta)
