# Declarative scenario/experiment subsystem: Scenario specs (function mix,
# arrival process, duration, backend matrix) executed by ExperimentRunner
# into machine-readable BENCH_<suite>.json artifacts with per-scenario
# histograms, knee/SLO metrics, and paper-claim deltas.
from repro.core.workload import ChainEdge, FusionPlan
from repro.experiments.artifacts import (build_artifact, latency_histogram,
                                         metric_row, metrics_csv,
                                         validate_artifact, write_artifact)
from repro.experiments.runner import ExperimentRunner
from repro.experiments.scenario import (DEFAULT_BACKENDS,
                                        DEFAULT_CLAIMS_PAIR, ArrivalSpec,
                                        AutoscalerSpec, FleetSpec,
                                        FunctionProfile, Scenario, SearchSpec,
                                        zipf_mix)
from repro.experiments.suites import (SMOKE_DURATION_SCALE, SUITES,
                                      build_scenarios, get_scenario,
                                      get_suite)

__all__ = [
    "ArrivalSpec", "AutoscalerSpec", "ChainEdge", "FleetSpec",
    "FunctionProfile", "FusionPlan", "Scenario", "SearchSpec", "zipf_mix",
    "DEFAULT_BACKENDS", "DEFAULT_CLAIMS_PAIR",
    "ExperimentRunner",
    "build_artifact", "latency_histogram", "metric_row", "metrics_csv",
    "validate_artifact", "write_artifact",
    "SMOKE_DURATION_SCALE", "SUITES", "build_scenarios", "get_scenario",
    "get_suite",
]
