"""The paper's own benchmark function: AES encryption of a 600-byte input
(vSwarm [23,24]), deployed as a junctiond FaaS function.  On TPU this is a
real Pallas AES-128-CTR kernel (repro.kernels.aes_ctr)."""
from repro.config import ArchConfig, ArchType, register


@register("paper-aes-600b")
def paper_aes() -> ArchConfig:
    return ArchConfig(
        name="paper-aes-600b",
        arch_type=ArchType.MICRO,
        citation="[vSwarm, arXiv this-paper §5]",
    )
