"""Qwen3-1.7B — dense, GQA (kv=8), qk-norm, large vocab.
[hf:Qwen/Qwen3-8B]"""
from repro.config import ArchConfig, ArchType, register


@register("qwen3-1.7b")
def qwen3_1p7b() -> ArchConfig:
    return ArchConfig(
        name="qwen3-1.7b",
        arch_type=ArchType.DENSE,
        citation="[hf:Qwen/Qwen3-8B]",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=6144,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        head_dim=128,
        tie_embeddings=True,
    )
