"""RWKV-6 (Finch) 1.6B — attention-free SSM with data-dependent decay.
[arXiv:2404.05892]"""
from repro.config import ArchConfig, ArchType, RWKVConfig, register


@register("rwkv6-1.6b")
def rwkv6_1p6b() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-1.6b",
        arch_type=ArchType.SSM,
        citation="[arXiv:2404.05892]",
        n_layers=24,
        d_model=2048,
        n_heads=0,             # attention-free
        n_kv_heads=0,
        d_ff=7168,
        vocab_size=65536,
        rwkv=RWKVConfig(head_size=64),
    )
