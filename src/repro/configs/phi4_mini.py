"""Phi-4-mini 3.8B — dense, RoPE, SwiGLU, GQA.
[arXiv:2412.08905]"""
from repro.config import ArchConfig, ArchType, register


@register("phi4-mini-3.8b")
def phi4_mini() -> ArchConfig:
    return ArchConfig(
        name="phi4-mini-3.8b",
        arch_type=ArchType.DENSE,
        citation="[arXiv:2412.08905]",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=200064,
        rope_theta=10_000.0,
        tie_embeddings=True,
    )
