"""SeamlessM4T-Large v2 — encoder-decoder multimodal transformer backbone.
The mel-spectrogram + conv feature extractor frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings.
[arXiv:2308.11596]"""
from repro.config import (ArchConfig, ArchType, EncDecConfig, FrontendStub,
                          register)


@register("seamless-m4t-large-v2")
def seamless_m4t_v2() -> ArchConfig:
    return ArchConfig(
        name="seamless-m4t-large-v2",
        arch_type=ArchType.AUDIO,
        citation="[arXiv:2308.11596]",
        n_layers=24,              # decoder layers (backbone)
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,            # GQA kv=16 == MHA here
        d_ff=8192,
        vocab_size=256206,
        rope_theta=10_000.0,
        encdec=EncDecConfig(encoder_layers=24, max_source_positions=1500),
        frontend=FrontendStub(kind="audio_frames", num_tokens=1500, embed_dim=1024),
    )
