"""Pixtral-12B — VLM: pixtral-ViT frontend (STUB) + mistral-nemo decoder.
The ViT vision encoder + projector is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings.
[hf:mistralai/Pixtral-12B-2409]"""
from repro.config import ArchConfig, ArchType, FrontendStub, register


@register("pixtral-12b")
def pixtral_12b() -> ArchConfig:
    return ArchConfig(
        name="pixtral-12b",
        arch_type=ArchType.VLM,
        citation="[hf:mistralai/Pixtral-12B-2409]",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1_000_000.0,
        head_dim=128,
        frontend=FrontendStub(kind="image_patches", num_tokens=1024, embed_dim=5120),
    )
