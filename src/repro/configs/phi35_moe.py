"""Phi-3.5-MoE 42B (6.6B active) — MoE 16 experts top-2, GQA.
[hf:microsoft/Phi-3.5-MoE-instruct]"""
from repro.config import ArchConfig, ArchType, MoEConfig, register


@register("phi3.5-moe-42b-a6.6b")
def phi35_moe() -> ArchConfig:
    return ArchConfig(
        name="phi3.5-moe-42b-a6.6b",
        arch_type=ArchType.MOE,
        citation="[hf:microsoft/Phi-3.5-MoE-instruct]",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        rope_theta=10_000.0,
        moe=MoEConfig(num_experts=16, top_k=2),
    )
