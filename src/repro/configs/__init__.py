"""Assigned-architecture configs.  Importing this package registers every
``--arch`` id in :mod:`repro.config.registry`."""
from repro.configs import (deepseek_67b, h2o_danube3_4b, jamba_v01,  # noqa: F401
                           mixtral_8x7b, paper_aes, phi35_moe, phi4_mini,
                           pixtral_12b, qwen3_1p7b, rwkv6_1p6b,
                           seamless_m4t_v2)

ASSIGNED = [
    "mixtral-8x7b",
    "phi3.5-moe-42b-a6.6b",
    "h2o-danube-3-4b",
    "qwen3-1.7b",
    "seamless-m4t-large-v2",
    "deepseek-67b",
    "phi4-mini-3.8b",
    "pixtral-12b",
    "jamba-v0.1-52b",
    "rwkv6-1.6b",
]
