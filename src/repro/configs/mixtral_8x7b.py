"""Mixtral-8x7B — MoE 8 experts top-2, GQA, sliding-window attention.
[arXiv:2401.04088]"""
from repro.config import ArchConfig, ArchType, MoEConfig, register


@register("mixtral-8x7b")
def mixtral_8x7b() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b",
        arch_type=ArchType.MOE,
        citation="[arXiv:2401.04088]",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        sliding_window=4096,
        rope_theta=1_000_000.0,
        moe=MoEConfig(num_experts=8, top_k=2),
    )
