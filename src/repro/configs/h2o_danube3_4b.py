"""H2O-Danube-3 4B — dense llama+mistral mix, GQA, sliding-window attention.
[arXiv:2401.16818]"""
from repro.config import ArchConfig, ArchType, register


@register("h2o-danube-3-4b")
def h2o_danube3() -> ArchConfig:
    return ArchConfig(
        name="h2o-danube-3-4b",
        arch_type=ArchType.DENSE,
        citation="[arXiv:2401.16818]",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_ff=10240,
        vocab_size=32000,
        sliding_window=4096,
        rope_theta=10_000.0,
    )
