"""Jamba v0.1 52B — hybrid Mamba + attention (1 attn per 8 blocks), MoE 16e
top-2 on every other block. [arXiv:2403.19887]"""
from repro.config import ArchConfig, ArchType, MambaConfig, MoEConfig, register


@register("jamba-v0.1-52b")
def jamba_v01() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b",
        arch_type=ArchType.HYBRID,
        citation="[arXiv:2403.19887]",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        moe=MoEConfig(num_experts=16, top_k=2),
        moe_every=2,          # MoE on every other block (Jamba e=2)
        attn_every=8,         # 1 attention layer per 8 (Mamba:attn 7:1)
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    )
