"""DeepSeek-67B — dense llama-architecture, deep (95L), GQA.
[arXiv:2401.02954]"""
from repro.config import ArchConfig, ArchType, register


@register("deepseek-67b")
def deepseek_67b() -> ArchConfig:
    return ArchConfig(
        name="deepseek-67b",
        arch_type=ArchType.DENSE,
        citation="[arXiv:2401.02954]",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        rope_theta=10_000.0,
    )
