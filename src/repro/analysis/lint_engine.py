"""simlint core: file loading, the two-pass rule driver, suppression.

The engine is deliberately small: it parses every ``.py`` file once with
the stdlib ``ast`` module, hands each :class:`SourceFile` to every
applicable rule's per-file ``check`` pass, then runs each rule's
cross-file ``finalize`` pass over the whole :class:`Project` (this is
how the registry-reachability rule sees both the ``@register_backend``
sites and the ``_BUILTIN_MODULES`` list they must appear in).

Findings are deterministic: files are visited in sorted path order and
the final report is sorted by ``(path, line, rule)`` — the linter obeys
the same no-unordered-iteration contract it enforces.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

from repro.analysis.lint_pragmas import parse_pragmas

#: rule id used for parse errors and malformed pragmas; not suppressible.
META_RULE = "pragma"


@dataclass(frozen=True)
class Finding:
    path: str           # root-relative posix path
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class SourceFile:
    """One parsed file plus its pragma table."""
    path: str                       # root-relative posix path
    tree: ast.Module
    lines: List[str]
    module: Optional[str]           # dotted module name, when derivable
    suppress: Dict[int, Set[str]] = field(default_factory=dict)

    def is_suppressed(self, line: int, rule: str) -> bool:
        return rule in self.suppress.get(line, ())


@dataclass
class Project:
    """All files in one lint run, for cross-file ``finalize`` passes."""
    files: List[SourceFile]

    def by_module(self) -> Dict[str, SourceFile]:
        return {f.module: f for f in self.files if f.module}


def module_name_of(relpath: str) -> Optional[str]:
    """Dotted module name for a root-relative path, or ``None``.

    ``src/repro/core/workload.py`` -> ``repro.core.workload``;
    ``tests/test_event_loop.py`` -> ``tests.test_event_loop``.
    """
    if not relpath.endswith(".py"):
        return None
    parts = relpath[:-3].split("/")
    if parts[0] == "src":
        parts = parts[1:]
    if not parts:
        return None
    if parts[-1] == "__init__":
        parts = parts[:-1]
    if not parts or not all(p.isidentifier() for p in parts):
        return None
    return ".".join(parts)


def iter_python_files(paths: Sequence[str], root: Path) -> List[Path]:
    """Expand files/directories into a sorted, de-duplicated file list."""
    seen: Set[Path] = set()
    out: List[Path] = []
    for p in paths:
        target = Path(p)
        if not target.is_absolute():
            target = root / target
        if target.is_dir():
            candidates = sorted(
                q for q in target.rglob("*.py")
                if "__pycache__" not in q.parts
                and not any(part.startswith(".") for part in q.parts))
        else:
            candidates = [target]
        for q in candidates:
            q = q.resolve()
            if q not in seen:
                seen.add(q)
                out.append(q)
    return out


def load_source_file(
    abspath: Path,
    root: Path,
    known_rules: Set[str],
) -> tuple[Optional[SourceFile], List[Finding]]:
    """Parse one file.  Returns ``(file_or_None, findings)`` — syntax
    errors and malformed pragmas surface as findings, not exceptions."""
    try:
        relpath = abspath.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        relpath = abspath.as_posix()
    try:
        text = abspath.read_text(encoding="utf-8")
    except OSError as exc:
        return None, [Finding(relpath, 1, META_RULE,
                              f"cannot read file: {exc}")]
    try:
        tree = ast.parse(text, filename=str(abspath))
    except SyntaxError as exc:
        return None, [Finding(relpath, exc.lineno or 1, META_RULE,
                              f"syntax error: {exc.msg}")]
    lines = text.splitlines()
    suppress, problems = parse_pragmas(lines, known_rules)
    findings = [Finding(relpath, p.line, META_RULE, p.message)
                for p in problems]
    sf = SourceFile(path=relpath, tree=tree, lines=lines,
                    module=module_name_of(relpath), suppress=suppress)
    return sf, findings


def run_lint(
    paths: Sequence[str],
    root: str = ".",
    rule_ids: Optional[Iterable[str]] = None,
) -> List[Finding]:
    """Lint ``paths`` (files or directories, relative to ``root``) with
    the selected rules (default: all registered).  Returns the sorted,
    suppression-filtered findings."""
    # imported here so `import repro.analysis.lint_engine` stays cheap
    # and rule registration happens exactly once, on first use
    from repro.analysis.lint_rules import RULES

    if rule_ids is None:
        rules = list(RULES.values())
    else:
        unknown = sorted(set(rule_ids) - set(RULES))
        if unknown:
            raise KeyError(f"unknown rule id(s): {', '.join(unknown)}")
        rules = [RULES[r] for r in rule_ids]

    rootp = Path(root)
    known = set(RULES)
    files: List[SourceFile] = []
    findings: List[Finding] = []
    for abspath in iter_python_files(paths, rootp):
        sf, extra = load_source_file(abspath, rootp, known)
        findings.extend(extra)
        if sf is not None:
            files.append(sf)

    project = Project(files)
    for rule in rules:
        for sf in files:
            if rule.applies(sf.path):
                findings.extend(rule.check(sf))
        findings.extend(rule.finalize(project))

    by_path = {f.path: f for f in files}
    kept = []
    for f in findings:
        if f.rule != META_RULE:
            sf = by_path.get(f.path)
            if sf is not None and sf.is_suppressed(f.line, f.rule):
                continue
        kept.append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    return kept
