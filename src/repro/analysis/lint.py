"""simlint CLI: ``python -m repro.analysis.lint src tests benchmarks``.

Exit codes: 0 = clean, 1 = findings, 2 = usage error.  Findings print
one per line as ``path:line: [rule-id] message``.  ``--list`` prints
the rule registry with each rule's one-line doc; ``--rules a,b``
restricts the run to a subset.

Suppress a finding with ``# simlint: allow[rule-id] reason`` on (or
directly above) the offending line — the reason is mandatory (see
``repro.analysis.lint_pragmas``).
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Determinism & contract lint for the repro "
                    "simulator (stdlib ast, no deps).")
    parser.add_argument("paths", nargs="*",
                        help="files or directories to lint "
                             "(e.g. src tests benchmarks)")
    parser.add_argument("--list", action="store_true", dest="list_rules",
                        help="list registered rules and exit")
    parser.add_argument("--verbose", action="store_true",
                        help="with --list, print each rule's full doc")
    parser.add_argument("--rules", default=None, metavar="ID[,ID...]",
                        help="run only this comma-separated rule subset")
    parser.add_argument("--root", default=".",
                        help="repo root paths are resolved against "
                             "(default: cwd)")
    args = parser.parse_args(argv)

    from repro.analysis.lint_rules import RULES

    if args.list_rules:
        width = max(len(r) for r in RULES)
        for rid in sorted(RULES):
            rule = RULES[rid]
            print(f"{rid:<{width}}  {rule.summary}")
            if args.verbose:
                for line in rule.doc.splitlines()[1:]:
                    print(f"{'':<{width}}  {line.strip()}")
                print()
        return 0

    if not args.paths:
        parser.print_usage(sys.stderr)
        print("error: no paths given (try: src tests benchmarks)",
              file=sys.stderr)
        return 2

    rule_ids = None
    if args.rules is not None:
        rule_ids = [r.strip() for r in args.rules.split(",") if r.strip()]

    from repro.analysis.lint_engine import run_lint
    try:
        findings = run_lint(args.paths, root=args.root, rule_ids=rule_ids)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 2

    for f in findings:
        print(f.render())
    if findings:
        print(f"simlint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
