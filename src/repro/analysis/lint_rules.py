"""simlint rules: the repo's determinism & API contracts, as AST checks.

Each rule mirrors the backend registry pattern: subclass :class:`Rule`,
decorate with :func:`register_rule`, and it appears in ``--list`` and in
the default rule set.  A rule's class docstring *is* its documentation —
the first line is the one-liner shown by ``--list``, the rest is shown
by ``--list --verbose``.

Rules run in two passes (see ``lint_engine``): ``check(file)`` yields
per-file findings; ``finalize(project)`` yields cross-file findings
after every file has been visited (used by the registry-reachability
and spec-kwargs rules, which need to pair definition sites in one file
with use sites in another).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple, Type

from repro.analysis.lint_engine import Finding, Project, SourceFile

RULES: Dict[str, "Rule"] = {}

#: deterministic-simulation modules: the event core and the fleet layer.
SIM_PATHS = ("src/repro/core/", "src/repro/fleet/")


def register_rule(cls: Type["Rule"]) -> Type["Rule"]:
    """Class decorator adding a rule to the global registry (the same
    shape as ``@register_backend`` in ``repro.core.backends``)."""
    rule = cls()
    if not rule.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if rule.id in RULES:
        raise ValueError(f"duplicate rule id {rule.id!r}")
    RULES[rule.id] = rule
    return cls


class Rule:
    #: stable identifier used in output and in ``allow[...]`` pragmas.
    id: str = ""
    #: root-relative path prefixes the per-file pass applies to
    #: (empty tuple = every file in the run).
    paths: Tuple[str, ...] = ()
    #: exact root-relative paths exempt from the per-file pass.
    exempt: Tuple[str, ...] = ()

    def applies(self, path: str) -> bool:
        if path in self.exempt:
            return False
        if not self.paths:
            return True
        return any(path.startswith(p) for p in self.paths)

    def check(self, f: SourceFile) -> Iterator[Finding]:
        return iter(())

    def finalize(self, project: Project) -> Iterator[Finding]:
        return iter(())

    @property
    def doc(self) -> str:
        return (self.__doc__ or "").strip()

    @property
    def summary(self) -> str:
        return self.doc.splitlines()[0] if self.doc else ""


def _terminal_name(node: ast.AST) -> Optional[str]:
    """The rightmost identifier of a Name / dotted Attribute chain."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _value_terminal(node: ast.AST) -> Optional[str]:
    """For ``a.b.c`` return ``b``'s terminal — i.e. the object a method
    is called on (``LoadSpec.single`` -> ``LoadSpec``)."""
    if isinstance(node, ast.Attribute):
        return _terminal_name(node.value)
    return None


# ---------------------------------------------------------------------------
# rule 1: wall-clock / randomness sources


@register_rule
class WallClockRule(Rule):
    """No wall-clock or ambient randomness in simulation code.

    ``time.time()``/``monotonic()``/``perf_counter()``,
    ``datetime.now()``/``utcnow()``/``today()``, and any use of the
    stdlib ``random`` or ``uuid`` modules make runs depend on the host
    instead of the seed.  Sim state must come from the simulator clock
    (``sim.now``) and the run's seeded ``numpy`` Generator.  Harness
    code in ``experiments/``/``launch/``/``benchmarks/`` that measures
    *host* elapsed time may suppress with
    ``# simlint: allow[wall-clock] <why>``.
    """

    id = "wall-clock"
    # sim code plus the pragma-gated harness layers; the JAX serving /
    # training stack (src/repro/serving, src/repro/train) measures real
    # host step time by design and is out of scope
    paths = SIM_PATHS + ("src/repro/experiments/", "src/repro/launch/",
                         "src/repro/analysis/", "benchmarks/")

    _WALL_FNS = frozenset({
        "time", "time_ns", "monotonic", "monotonic_ns",
        "perf_counter", "perf_counter_ns", "process_time",
        "process_time_ns"})
    _DT_FNS = frozenset({"now", "utcnow", "today"})

    def check(self, f: SourceFile) -> Iterator[Finding]:
        time_aliases: Set[str] = set()
        dt_mod_aliases: Set[str] = set()    # `import datetime [as d]`
        dt_cls_aliases: Set[str] = set()    # `from datetime import datetime`
        wall_fn_aliases: Set[str] = set()   # `from time import time [as t]`

        for node in ast.walk(f.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    top = alias.name.split(".")[0]
                    local = alias.asname or top
                    if top in ("random", "uuid"):
                        yield Finding(
                            f.path, node.lineno, self.id,
                            f"import of nondeterministic module "
                            f"{top!r}; draw from the run's seeded "
                            f"numpy Generator instead")
                    elif top == "time":
                        time_aliases.add(local)
                    elif top == "datetime":
                        dt_mod_aliases.add(local)
            elif isinstance(node, ast.ImportFrom):
                mod = (node.module or "").split(".")[0]
                if mod in ("random", "uuid"):
                    yield Finding(
                        f.path, node.lineno, self.id,
                        f"import from nondeterministic module {mod!r}; "
                        f"draw from the run's seeded numpy Generator "
                        f"instead")
                elif mod == "time":
                    for alias in node.names:
                        if alias.name in self._WALL_FNS:
                            wall_fn_aliases.add(alias.asname or alias.name)
                elif mod == "datetime":
                    for alias in node.names:
                        if alias.name in ("datetime", "date"):
                            dt_cls_aliases.add(alias.asname or alias.name)

        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in wall_fn_aliases:
                yield Finding(
                    f.path, node.lineno, self.id,
                    f"wall-clock read {fn.id}(); use sim.now for sim "
                    f"time")
            elif isinstance(fn, ast.Attribute):
                base = fn.value
                if (isinstance(base, ast.Name) and base.id in time_aliases
                        and fn.attr in self._WALL_FNS):
                    yield Finding(
                        f.path, node.lineno, self.id,
                        f"wall-clock read {base.id}.{fn.attr}(); use "
                        f"sim.now for sim time")
                elif fn.attr in self._DT_FNS:
                    if (isinstance(base, ast.Name)
                            and base.id in dt_cls_aliases):
                        yield Finding(
                            f.path, node.lineno, self.id,
                            f"wall-clock read {base.id}.{fn.attr}(); "
                            f"use sim.now for sim time")
                    elif (isinstance(base, ast.Attribute)
                          and base.attr in ("datetime", "date")
                          and isinstance(base.value, ast.Name)
                          and base.value.id in dt_mod_aliases):
                        yield Finding(
                            f.path, node.lineno, self.id,
                            f"wall-clock read "
                            f"{base.value.id}.{base.attr}.{fn.attr}(); "
                            f"use sim.now for sim time")


# ---------------------------------------------------------------------------
# rule 2: unordered iteration / address-keyed ordering


@register_rule
class UnorderedIterationRule(Rule):
    """No bare set iteration or ``hash()``/``id()``-keyed ordering in
    sim code.

    Iteration order over a ``set`` (and ordering by builtin ``hash()``
    or ``id()``) varies with PYTHONHASHSEED and allocation order; when
    it feeds event scheduling, same-seed runs diverge.  Wrap the
    iterable in ``sorted(...)`` or key on ``zlib.crc32`` instead.
    """

    id = "unordered-iter"
    paths = SIM_PATHS

    _SET_CALLS = frozenset({"set", "frozenset"})
    _SET_METHODS = frozenset({
        "intersection", "union", "difference", "symmetric_difference"})

    def _is_unordered(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in self._SET_CALLS:
                return True
            if (isinstance(fn, ast.Attribute)
                    and fn.attr in self._SET_METHODS):
                return True
        return False

    def check(self, f: SourceFile) -> Iterator[Finding]:
        for node in ast.walk(f.tree):
            iters: List[ast.AST] = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.GeneratorExp, ast.DictComp)):
                iters.extend(g.iter for g in node.generators)
            for it in iters:
                if self._is_unordered(it):
                    yield Finding(
                        f.path, it.lineno, self.id,
                        "iteration over an unordered set in sim code; "
                        "wrap in sorted(...)")
            if isinstance(node, ast.Call):
                fn = node.func
                if isinstance(fn, ast.Name) and fn.id in ("hash", "id"):
                    yield Finding(
                        f.path, node.lineno, self.id,
                        f"builtin {fn.id}() is not stable across runs; "
                        f"use zlib.crc32 for deterministic hashing")
                for kw in node.keywords:
                    if (kw.arg == "key" and isinstance(kw.value, ast.Name)
                            and kw.value.id in ("hash", "id")):
                        yield Finding(
                            f.path, node.lineno, self.id,
                            f"ordering keyed on builtin {kw.value.id} "
                            f"is not stable across runs")


# ---------------------------------------------------------------------------
# rule 3: registry reachability (cross-file)


@register_rule
class RegistryReachableRule(Rule):
    """Every registered backend/placement/distribution module must be
    imported somewhere the registry can see.

    ``@register_backend`` (and the fleet ``@register_placement`` /
    ``@register_distribution``) decorators only run on import: a module
    that registers a class but is missing from ``_BUILTIN_MODULES`` (or,
    for fleet registries, from the ``fleet/__init__`` imports) is
    silently absent from ``available_backends()`` et al.
    """

    id = "registry-reachable"

    _DECOS = {
        "register_backend": "backend",
        "register_placement": "placement",
        "register_distribution": "distribution",
    }

    def finalize(self, project: Project) -> Iterator[Finding]:
        registered: List[Tuple[str, str, SourceFile, int]] = []
        builtin_lists: List[Set[str]] = []
        fleet_init_imports: Set[str] = set()
        saw_fleet_init = False

        for f in project.files:
            for node in ast.walk(f.tree):
                if isinstance(node, ast.ClassDef):
                    for deco in node.decorator_list:
                        target = deco.func if isinstance(deco, ast.Call) \
                            else deco
                        name = _terminal_name(target)
                        # registrations in tests/benchmarks are
                        # deliberately transient fixtures; only shipped
                        # modules must be import-reachable
                        if (name in self._DECOS and f.module
                                and f.path.startswith("src/")):
                            registered.append((self._DECOS[name], f.module,
                                               f, node.lineno))
                elif isinstance(node, ast.Assign):
                    for t in node.targets:
                        if (isinstance(t, ast.Name)
                                and t.id.endswith("_BUILTIN_MODULES")
                                and isinstance(node.value,
                                               (ast.Tuple, ast.List))):
                            mods = {e.value for e in node.value.elts
                                    if isinstance(e, ast.Constant)
                                    and isinstance(e.value, str)}
                            builtin_lists.append(mods)
            if f.path.endswith("/__init__.py") and f.module \
                    and f.module.endswith(".fleet"):
                saw_fleet_init = True
                for node in ast.walk(f.tree):
                    if isinstance(node, ast.ImportFrom) and node.module:
                        fleet_init_imports.add(node.module)
                        for alias in node.names:
                            fleet_init_imports.add(
                                f"{node.module}.{alias.name}")
                    elif isinstance(node, ast.Import):
                        for alias in node.names:
                            fleet_init_imports.add(alias.name)

        listed: Set[str] = set().union(*builtin_lists) if builtin_lists \
            else set()
        for kind, module, f, lineno in sorted(
                registered, key=lambda r: (r[2].path, r[3])):
            if kind == "backend":
                # only judged when a _BUILTIN_MODULES list is in the run
                if not builtin_lists or module in listed:
                    continue
                yield Finding(
                    f.path, lineno, self.id,
                    f"module {module!r} registers a backend but is not "
                    f"in _BUILTIN_MODULES, so resolve_backend() will "
                    f"never see it")
            else:
                if not saw_fleet_init:
                    continue
                if module in listed or module in fleet_init_imports:
                    continue
                yield Finding(
                    f.path, lineno, self.id,
                    f"module {module!r} registers a {kind} but is not "
                    f"imported from the fleet package __init__, so the "
                    f"registry will never see it")


# ---------------------------------------------------------------------------
# rule 4: float equality on rates / times


@register_rule
class FloatEqualityRule(Rule):
    """No ``==``/``!=`` between float-typed sim quantities (rates,
    times, latencies).

    Rates and times are accumulated floats; exact comparison silently
    never matches after any arithmetic (the PR-5 knee-row bug class).
    Compare with a tolerance, or match on the integer/index that
    produced the float.
    """

    id = "float-eq"
    paths = SIM_PATHS + ("src/repro/experiments/", "benchmarks/")

    _NAME_SUFFIXES = (
        "_s", "_t", "_us", "_ms", "_ns", "_rps", "_rate", "_time",
        "_frac", "_tol", "_lat", "_latency", "_gbps", "_mbps")
    _NAMES = frozenset({
        "t", "t0", "t1", "now", "rate", "rps", "knee", "dt", "lat",
        "latency", "elapsed", "dur", "duration"})

    def _timeish(self, node: ast.AST) -> bool:
        name = None
        if isinstance(node, (ast.Name, ast.Attribute)):
            name = _terminal_name(node)
        elif (isinstance(node, ast.Subscript)
              and isinstance(node.slice, ast.Constant)
              and isinstance(node.slice.value, str)):
            name = node.slice.value
        if name is None:
            return False
        return (name in self._NAMES
                or name.endswith(self._NAME_SUFFIXES))

    def _floaty(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant) and isinstance(node.value, float):
            return True
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id == "float"):
            return True
        return False

    def check(self, f: SourceFile) -> Iterator[Finding]:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Compare):
                continue
            left = node.left
            for op, right in zip(node.ops, node.comparators):
                if isinstance(op, (ast.Eq, ast.NotEq)):
                    lh = self._timeish(left) or self._floaty(left)
                    rh = self._timeish(right) or self._floaty(right)
                    if lh and rh:
                        yield Finding(
                            f.path, node.lineno, self.id,
                            "exact float equality on a rate/time "
                            "quantity; compare with a tolerance or "
                            "match on the producing index")
                left = right


# ---------------------------------------------------------------------------
# rule 5: deprecated shim call sites


SHIM_NAMES = frozenset({"run_open_loop", "run_mixed_open_loop"})

#: files allowed to reference the shims: the definitions, the package
#: re-export, and the deprecation test that pins their behaviour.
SHIM_EXEMPT = (
    "src/repro/core/workload.py",
    "src/repro/core/__init__.py",
    "tests/test_event_loop.py",
)


def iter_shim_references(tree: ast.Module) -> Iterator[Tuple[int, str]]:
    """Yield ``(lineno, name)`` for every call of / import of a
    deprecated shim in ``tree`` (shared with the pin test in
    ``tests/test_event_loop.py``)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _terminal_name(node.func)
            if name in SHIM_NAMES:
                yield node.lineno, name
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name in SHIM_NAMES:
                    yield node.lineno, alias.name


def count_shim_call_sites(paths, root=".") -> int:
    """Count deprecated-shim *call* sites (not imports) across a tree,
    including the exempt files.  Used by the deprecation test to pin the
    total to an exact number."""
    from repro.analysis.lint_engine import iter_python_files, \
        load_source_file
    from pathlib import Path
    n = 0
    for abspath in iter_python_files(paths, Path(root)):
        sf, _ = load_source_file(abspath, Path(root), set(RULES))
        if sf is None:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call) \
                    and _terminal_name(node.func) in SHIM_NAMES:
                n += 1
    return n


@register_rule
class DeprecatedShimRule(Rule):
    """No new call sites of the deprecated ``run_open_loop`` /
    ``run_mixed_open_loop`` shims.

    Both delegate to ``drive(runtime, LoadSpec, ...)`` and warn; new
    code must call ``drive`` directly.  Only the shim definitions, the
    ``repro.core`` re-export, and the deprecation test may reference
    them.
    """

    id = "deprecated-shim"
    exempt = SHIM_EXEMPT

    def check(self, f: SourceFile) -> Iterator[Finding]:
        for lineno, name in iter_shim_references(f.tree):
            yield Finding(
                f.path, lineno, self.id,
                f"deprecated shim {name}(); call drive(runtime, "
                f"LoadSpec, ...) instead")


# ---------------------------------------------------------------------------
# rule 6: frozen-dataclass mutation outside __post_init__


@register_rule
class FrozenMutationRule(Rule):
    """``object.__setattr__`` only inside ``__post_init__``.

    Frozen dataclasses (LoadSpec, Scenario, the spec family) may only
    normalise their own fields during construction; mutating one
    anywhere else silently bypasses both the freeze and validation.
    Build a new instance with ``dataclasses.replace`` instead.
    """

    id = "frozen-setattr"
    paths = ("src/repro/",)

    def check(self, f: SourceFile) -> Iterator[Finding]:
        # walk with an explicit function-name stack so each call knows
        # its innermost enclosing def
        stack: List[str] = []

        def visit(node: ast.AST) -> Iterator[Finding]:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack.append(node.name)
                for child in ast.iter_child_nodes(node):
                    yield from visit(child)
                stack.pop()
                return
            if isinstance(node, ast.Call):
                fn = node.func
                if (isinstance(fn, ast.Attribute)
                        and fn.attr == "__setattr__"
                        and isinstance(fn.value, ast.Name)
                        and fn.value.id == "object"
                        and (not stack or stack[-1] != "__post_init__")):
                    yield Finding(
                        f.path, node.lineno, self.id,
                        "object.__setattr__ outside __post_init__ "
                        "mutates a frozen dataclass; use "
                        "dataclasses.replace")
            for child in ast.iter_child_nodes(node):
                yield from visit(child)

        yield from visit(f.tree)


# ---------------------------------------------------------------------------
# rule 7: scheduling at a non-delay time expression


@register_rule
class SchedulePastRule(Rule):
    """Delays passed to ``timeout``/``_schedule`` must be relative, not
    absolute.

    The heap orders on absolute time computed as ``now + delay``;
    passing an absolute timestamp (any ``.now``-positive expression) or
    a negative constant schedules the event far in the future or in the
    past.  A correct absolute-to-relative conversion subtracts ``now``
    (``t - sim.now``), which this rule recognises by sign analysis.
    """

    id = "sched-past"
    paths = SIM_PATHS

    _SCHED_FNS = frozenset({"timeout", "_schedule", "schedule"})

    def _now_signs(self, node: ast.AST, sign: int, out: List[int]) -> None:
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, ast.Add):
                self._now_signs(node.left, sign, out)
                self._now_signs(node.right, sign, out)
                return
            if isinstance(node.op, ast.Sub):
                self._now_signs(node.left, sign, out)
                self._now_signs(node.right, -sign, out)
                return
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            self._now_signs(node.operand, -sign, out)
            return
        name = _terminal_name(node) if isinstance(
            node, (ast.Name, ast.Attribute)) else None
        if name == "now":
            out.append(sign)
        # other node kinds (calls, subscripts) are opaque: no recursion,
        # so `max(0.0, t - now)` claims nothing about `now`

    def check(self, f: SourceFile) -> Iterator[Finding]:
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            if _terminal_name(node.func) not in self._SCHED_FNS:
                continue
            delay = node.args[0]
            if isinstance(delay, ast.UnaryOp) \
                    and isinstance(delay.op, ast.USub) \
                    and isinstance(delay.operand, ast.Constant):
                yield Finding(
                    f.path, node.lineno, self.id,
                    "negative constant delay schedules an event in "
                    "the past")
                continue
            signs: List[int] = []
            self._now_signs(delay, 1, signs)
            if signs and min(signs) > 0:
                yield Finding(
                    f.path, node.lineno, self.id,
                    "absolute time passed as a delay (a `now` term "
                    "with positive sign and no `- now`); pass "
                    "`t - sim.now` instead")


# ---------------------------------------------------------------------------
# rule 8: spec construction with unknown kwargs (cross-file)


@register_rule
class SpecKwargsRule(Rule):
    """``LoadSpec``/``Scenario``-family constructors must only receive
    known field names.

    The spec dataclasses are data-only: a misspelled kwarg raises
    ``TypeError`` at runtime, but only on the code path that builds it —
    a scenario file with a typo'd field can sit broken until the suite
    reaches it.  This rule checks every literal construction against
    the dataclass's declared fields.
    """

    id = "spec-kwargs"

    _SPEC_CLASSES = frozenset({
        "LoadSpec", "Scenario", "FunctionProfile", "ArrivalSpec",
        "AutoscalerSpec", "SearchSpec", "FleetSpec"})

    def finalize(self, project: Project) -> Iterator[Finding]:
        fields: Dict[str, Set[str]] = {}
        # classmethod alt-constructors: name -> (params, has_kwargs)
        methods: Dict[Tuple[str, str], Tuple[Set[str], bool]] = {}

        for f in project.files:
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.ClassDef) \
                        or node.name not in self._SPEC_CLASSES:
                    continue
                if not any(_terminal_name(
                        d.func if isinstance(d, ast.Call) else d)
                        == "dataclass" for d in node.decorator_list):
                    continue
                fs: Set[str] = set()
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) \
                            and isinstance(stmt.target, ast.Name) \
                            and not stmt.target.id.startswith("_"):
                        ann = ast.dump(stmt.annotation)
                        if "ClassVar" not in ann:
                            fs.add(stmt.target.id)
                    elif isinstance(stmt, (ast.FunctionDef,
                                           ast.AsyncFunctionDef)):
                        is_cm = any(_terminal_name(d) == "classmethod"
                                    for d in stmt.decorator_list)
                        if is_cm:
                            a = stmt.args
                            params = {p.arg for p in
                                      (a.args[1:] + a.kwonlyargs)}
                            methods[(node.name, stmt.name)] = (
                                params, a.kwarg is not None)
                fields[node.name] = fs

        if not fields:
            return

        for f in project.files:
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                name = _terminal_name(fn)
                valid: Optional[Set[str]] = None
                label = name
                if name in fields:
                    valid = fields[name]
                elif isinstance(fn, ast.Attribute):
                    owner = _value_terminal(fn)
                    if owner in fields and (owner, name) in methods:
                        params, has_kwargs = methods[(owner, name)]
                        if has_kwargs:
                            # e.g. LoadSpec.single(**kw): kw forwards to
                            # the dataclass, so check against its fields
                            valid = fields[owner] | params
                        else:
                            valid = params
                        label = f"{owner}.{name}"
                if valid is None:
                    continue
                for kw in node.keywords:
                    if kw.arg is not None and kw.arg not in valid:
                        yield Finding(
                            f.path, node.lineno, self.id,
                            f"unknown kwarg {kw.arg!r} for {label}(); "
                            f"valid: {', '.join(sorted(valid))}")
