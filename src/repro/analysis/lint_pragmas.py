"""Suppression pragmas for simlint.

A finding is suppressed by an inline comment on the offending line::

    t0 = time.time()   # simlint: allow[wall-clock] measures host elapsed

or by a comment-only line immediately above it::

    # simlint: allow[wall-clock] measures host elapsed
    t0 = time.time()

The reason after the closing bracket is mandatory: a pragma without one
is itself reported as a finding (rule id ``pragma``), so every
suppression in the tree documents *why* the contract does not apply.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Set, Tuple

# A pragma reads `simlint: allow[rule-id] reason` after a `#` — verb
# and rule id are captured so that unknown verbs ("ignore", "disable")
# fail loudly instead of silently not suppressing anything.  The
# lookbehind skips a `#` immediately preceded by a quote or backtick,
# so pragma examples inside string literals and docstrings (including
# this module's own) are not parsed as pragmas.
_PRAGMA_RE = re.compile(
    r"(?<![\"'`])#\s*simlint:\s*(?P<verb>[A-Za-z_-]+)\s*"
    r"\[(?P<rule>[A-Za-z0-9_-]*)\]\s*(?P<reason>.*)$")

# Anything that merely *mentions* simlint right after a `#`, used to
# catch malformed pragmas that the strict regex above would skip.
_LOOSE_RE = re.compile(r"(?<![\"'`])#\s*simlint:")

MIN_REASON_LEN = 3


@dataclass(frozen=True)
class PragmaProblem:
    """A malformed pragma (wrong verb, no rule id, missing reason)."""
    line: int
    message: str


def parse_pragmas(
    lines: List[str],
    known_rules: Set[str],
) -> Tuple[Dict[int, Set[str]], List[PragmaProblem]]:
    """Scan source ``lines`` for suppression pragmas.

    Returns ``(suppressions, problems)`` where ``suppressions`` maps a
    1-based line number to the set of rule ids suppressed on that line.
    A pragma on a comment-only line anchors to the next line; a trailing
    pragma anchors to its own line.
    """
    suppress: Dict[int, Set[str]] = {}
    problems: List[PragmaProblem] = []
    for lineno, raw in enumerate(lines, start=1):
        if "simlint" not in raw:
            continue
        m = _PRAGMA_RE.search(raw)
        if m is None:
            if _LOOSE_RE.search(raw):
                problems.append(PragmaProblem(
                    lineno,
                    "malformed simlint pragma; expected "
                    "'# simlint: allow[rule-id] reason'"))
            continue
        verb = m.group("verb")
        rule = m.group("rule")
        reason = m.group("reason").strip()
        if verb != "allow":
            problems.append(PragmaProblem(
                lineno, f"unknown simlint pragma verb {verb!r}; "
                        f"only 'allow' is supported"))
            continue
        if not rule:
            problems.append(PragmaProblem(
                lineno, "simlint pragma is missing a rule id: "
                        "'# simlint: allow[rule-id] reason'"))
            continue
        if known_rules and rule not in known_rules:
            problems.append(PragmaProblem(
                lineno, f"simlint pragma names unknown rule {rule!r}"))
            continue
        if len(reason) < MIN_REASON_LEN:
            problems.append(PragmaProblem(
                lineno, f"simlint pragma for [{rule}] requires a reason "
                        f"after the bracket"))
            continue
        # comment-only lines anchor the suppression to the next line
        anchor = lineno
        if raw.lstrip().startswith("#"):
            anchor = lineno + 1
        suppress.setdefault(anchor, set()).add(rule)
        # a trailing pragma also covers its own line even when the
        # statement spans several physical lines ending here
        if anchor != lineno:
            suppress.setdefault(lineno, set()).add(rule)
    return suppress, problems
