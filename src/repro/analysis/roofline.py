"""Three-term roofline from compiled dry-run artifacts.

    compute_term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory_term     = HLO_bytes / (chips x 819 GB/s)
    collective_term = collective_bytes_per_chip / link_bw (~50 GB/s/link)

XLA's ``cost_analysis`` counts a ``while`` (lax.scan) body ONCE, so a
full-model lowering under-reports per-layer work.  We therefore lower two
*unrolled* probe variants at small layer counts (L_a < L_b), fit the
linear model F(L) = base + L * per_layer for flops / bytes / collective
traffic, and extrapolate to the real depth.  Inner SSM time-chunk scans
remain under-counted inside a probe body; their FLOP share is <1% of the
layer matmuls for every assigned config (analysed in EXPERIMENTS.md), so
this residual is ignored.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.analysis.hlo_parse import parse_collectives
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class ProbePoint:
    layers: int
    flops: float            # per-chip, from cost_analysis
    bytes_accessed: float   # per-chip
    coll_bytes: float       # per-chip, from HLO parse


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # extrapolated per-chip totals per step
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    # the three terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float      # 6ND / 2ND analytic (global)
    useful_ratio: float     # model_flops / (hlo_flops * chips)
    step_time_s: float      # max of the three terms
    memory_per_chip_gb: Optional[float] = None
    notes: str = ""

    def as_row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_ms": self.compute_s * 1e3,
            "memory_ms": self.memory_s * 1e3,
            "collective_ms": self.collective_s * 1e3,
            "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "step_ms": self.step_time_s * 1e3,
            "mem_gb": self.memory_per_chip_gb,
        }


def extrapolate(pa: ProbePoint, pb: ProbePoint, layers: int):
    """Linear fit through two probe points, evaluated at `layers`."""
    dl = pb.layers - pa.layers
    assert dl > 0

    def fit(a, b):
        per_layer = (b - a) / dl
        base = a - pa.layers * per_layer
        return base + layers * per_layer, per_layer

    flops, flops_pl = fit(pa.flops, pb.flops)
    byts, _ = fit(pa.bytes_accessed, pb.bytes_accessed)
    coll, coll_pl = fit(pa.coll_bytes, pb.coll_bytes)
    return {"flops": max(flops, pb.flops), "bytes": max(byts, pb.bytes_accessed),
            "coll": max(coll, 0.0),
            "flops_per_layer": flops_pl, "coll_per_layer": coll_pl}


def probe_from_compiled(layers: int, compiled) -> ProbePoint:
    ca = compiled.cost_analysis()
    txt = compiled.as_text()
    coll = parse_collectives(txt)
    return ProbePoint(
        layers=layers,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        coll_bytes=coll.bytes_per_chip,
    )


def build_roofline(arch: str, shape: str, mesh_name: str, chips: int,
                   totals: Dict[str, float], model_flops: float,
                   memory_per_chip_gb: Optional[float] = None,
                   ici_links: int = 4, notes: str = "") -> Roofline:
    compute_s = totals["flops"] / PEAK_FLOPS_BF16
    memory_s = totals["bytes"] / HBM_BW
    collective_s = totals["coll"] / (ICI_BW * ici_links)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(1.0, totals["flops"] * chips)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=totals["flops"], hlo_bytes=totals["bytes"],
        coll_bytes=totals["coll"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=useful, step_time_s=max(terms.values()),
        memory_per_chip_gb=memory_per_chip_gb, notes=notes)
