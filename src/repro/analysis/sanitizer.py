"""Runtime sim-sanitizer: dynamic invariant checks for the event core.

Static analysis (``repro.analysis.lint``) catches contract violations
visible in source; this module catches the ones only visible at run
time.  When installed it wraps the simulator's hot paths with checked
variants that assert, on every transition:

* **clock monotonicity** — ``EventLoop.run`` / ``Simulator.run`` never
  pop an event timestamped before the current clock, and presorted
  arrival streams never go backwards;
* **no scheduling into the past** — ``Simulator._schedule`` rejects
  negative delays (beyond float-roundoff tolerance);
* **CorePool capacity** — ``busy`` never goes negative, and an
  increment never jumps the pool from strictly below capacity to over
  capacity (a full pool may legitimately go one over: Event-waiter
  grants defer their increment to resume time, and ``remove_cores``
  can shrink ``n_cores`` under the held count);
* **pending-releases ⇒ no-waiters** — ``release_at`` refuses to queue a
  lazy release while waiters exist, and a waiter cannot be appended
  while lazy releases are pending (callers must ``_materialize``
  first);
* **fused fast path** — the fused-admit branches in
  ``repro.core.workload`` and ``repro.fleet.driver`` call
  :func:`fused_admit_check` (gated on ``workload.SIM_CHECK``, the same
  zero-overhead module-flag pattern as ``FUSED_FAST_PATH``) to assert
  the pool is genuinely uncontended and the precomputed completion
  times lie ahead of the clock.

Enable for a whole process with ``REPRO_SIM_CHECK=1`` (hooked at the
end of ``repro.core.__init__``), or programmatically::

    from repro.analysis import sanitizer
    sanitizer.install()
    try:
        ...
    finally:
        sanitizer.uninstall()

The checked wrappers are operation-for-operation copies of the
originals, so checked runs are byte-identical to unchecked runs; when
not installed the only residual cost is one module-level boolean read
per fused admit.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Any, Callable, Dict, Optional

#: absolute tolerance (seconds) absorbing float roundoff in delay and
#: past-event checks.
TOL = 1e-9


class SimCheckError(AssertionError):
    """A dynamic simulator invariant was violated."""


_installed = False
_saved: Dict[str, Any] = {}


def enabled() -> bool:
    return _installed


class _CheckedWaiters(deque):
    """CorePool waiter deque asserting the pending-releases⇒no-waiters
    invariant on every append."""

    __slots__ = ("pool",)

    def append(self, item: Any) -> None:
        if _installed and self.pool._off_pend:
            raise SimCheckError(
                "CorePool waiter queued while lazy releases are "
                "pending; callers must _materialize() first")
        deque.append(self, item)


def fused_admit_check(pool: Any, t: float, end_t: float,
                      off_end_t: Optional[float] = None) -> None:
    """Assert a fused fast-path admit is legitimate: the pool is
    uncontended and the precomputed timeline lies ahead of the clock.
    Called from the fused branches in ``workload._drive_events`` and
    ``fleet.driver.drive_cluster`` when ``workload.SIM_CHECK`` is on."""
    if pool._waiters:
        raise SimCheckError(
            "fused fast path admitted while the pool has waiters "
            "(contended pools must take the per-station path)")
    if end_t < t - TOL:
        raise SimCheckError(
            f"fused completion at {end_t} precedes the admit at {t}")
    if off_end_t is not None and off_end_t < t - TOL:
        raise SimCheckError(
            f"fused off-path release at {off_end_t} precedes the "
            f"admit at {t}")


def install() -> None:
    """Swap the checked wrappers in.  Idempotent."""
    global _installed
    if _installed:
        return
    from repro.core import resources, simulator, workload

    CorePool = resources.CorePool
    Simulator = simulator.Simulator
    EventLoop = simulator.EventLoop

    _saved["busy_slot"] = busy_slot = CorePool.busy      # member descriptor
    _saved["release_at"] = orig_release_at = CorePool.release_at
    _saved["pool_init"] = orig_pool_init = CorePool.__init__
    _saved["schedule"] = orig_schedule = Simulator._schedule
    _saved["sim_run"] = Simulator.run
    _saved["loop_run"] = EventLoop.run

    # -- CorePool.busy: a validating property over the slot -------------
    def _busy_get(self: Any) -> int:
        return busy_slot.__get__(self, CorePool)

    def _busy_set(self: Any, value: int) -> None:
        try:
            old = busy_slot.__get__(self, CorePool)
        except AttributeError:
            old = None              # first assignment, in __init__
        if value < 0:
            raise SimCheckError(f"CorePool.busy went negative ({value})")
        nc = self.n_cores
        # a pool already at/over capacity may legitimately gain one more
        # hold: an Event-waiter grant defers its increment to resume
        # time (and remove_cores can shrink under the held count), so
        # only an increment that *jumps* from strictly below capacity to
        # above it is provably corrupt
        if old is not None and old < nc < value:
            raise SimCheckError(
                f"CorePool.busy incremented past capacity "
                f"({old} -> {value} with n_cores={nc})")
        busy_slot.__set__(self, value)

    setattr(CorePool, "busy", property(_busy_get, _busy_set))

    # -- pending-releases ⇒ no-waiters -----------------------------------
    def _checked_release_at(self: Any, t: float) -> None:
        if self._waiters:
            raise SimCheckError(
                "CorePool.release_at while waiters are queued "
                "(pending-releases => no-waiters invariant)")
        if t < self.sim.now - TOL:
            raise SimCheckError(
                f"lazy core release at {t} is in the past "
                f"(now={self.sim.now})")
        orig_release_at(self, t)

    CorePool.release_at = _checked_release_at

    def _checked_pool_init(self: Any, sim: Any, n_cores: int,
                           runtime: Any) -> None:
        orig_pool_init(self, sim, n_cores, runtime)
        w = _CheckedWaiters()
        w.pool = self
        self._waiters = w

    CorePool.__init__ = _checked_pool_init

    # -- no scheduling into the past -------------------------------------
    def _checked_schedule(self: Any, delay: float, fn: Callable,
                          *args: Any) -> None:
        if delay < -TOL:
            raise SimCheckError(
                f"negative delay {delay} schedules an event in the past")
        orig_schedule(self, delay, fn, *args)

    Simulator._schedule = _checked_schedule

    # -- clock monotonicity: checked copies of both run loops ------------
    # Operation-for-operation copies of the originals (see
    # repro.core.simulator) so checked runs stay byte-identical.
    def _checked_sim_run(self: Any, until: float = float("inf")) -> None:
        self.stopped = False
        while self._heap and not self.stopped:
            t, _, fn, args = self._heap[0]
            if t > until:
                break
            if t < self.now - TOL:
                raise SimCheckError(
                    f"event at {t} pops with the clock at {self.now}")
            heapq.heappop(self._heap)
            self.now = t
            fn(*args)
        if until != float("inf") and not self.stopped:
            self.now = max(self.now, until)

    Simulator.run = _checked_sim_run

    def _checked_loop_run(self: Any, until: float, arrival_times: Any = None,
                          admit: Any = None) -> int:
        sim = self.sim
        heap = sim._heap
        pop = heapq.heappop
        arr = arrival_times if arrival_times is not None else ()
        n_arr = len(arr)
        inf = float("inf")
        i = 0
        t_ar = arr[0] if n_arr else inf
        sim.stopped = False
        while not sim.stopped:
            t_ev = heap[0][0] if heap else inf
            if t_ar <= t_ev:
                if t_ar > until:
                    break
                if t_ar < sim.now - TOL:
                    raise SimCheckError(
                        f"arrival stream goes backwards: {t_ar} with "
                        f"the clock at {sim.now}")
                sim.now = t_ar
                admit(i, t_ar)
                i += 1
                t_ar = arr[i] if i < n_arr else inf
            else:
                if t_ev > until:
                    break
                if t_ev < sim.now - TOL:
                    raise SimCheckError(
                        f"event at {t_ev} pops with the clock at "
                        f"{sim.now}")
                t, _, fn, args = pop(heap)
                sim.now = t
                fn(*args)
        if not sim.stopped:
            sim.now = max(sim.now, until)
        return i

    EventLoop.run = _checked_loop_run

    # -- fused-admit checks in the flat drivers --------------------------
    workload.SIM_CHECK = True

    _installed = True


def uninstall() -> None:
    """Restore the unchecked originals.  Idempotent."""
    global _installed
    if not _installed:
        return
    from repro.core import resources, simulator, workload

    setattr(resources.CorePool, "busy", _saved["busy_slot"])
    resources.CorePool.release_at = _saved["release_at"]
    resources.CorePool.__init__ = _saved["pool_init"]
    simulator.Simulator._schedule = _saved["schedule"]
    simulator.Simulator.run = _saved["sim_run"]
    simulator.EventLoop.run = _saved["loop_run"]
    workload.SIM_CHECK = False
    _saved.clear()
    _installed = False
