"""Parse collective ops (+ their traffic) out of post-SPMD compiled HLO.

``compiled.as_text()`` exposes the partitioned module; we sum the bytes
each collective moves per chip:

  all-gather       : out_bytes * (n-1)/n
  reduce-scatter   : in_bytes  * (n-1)/n
  all-reduce       : 2 * bytes * (n-1)/n     (ring = RS + AG)
  all-to-all       : bytes * (n-1)/n
  collective-permute: bytes

CAVEAT (documented in EXPERIMENTS.md): collectives inside a `while` body
appear once in the text; the roofline module therefore derives per-layer
traffic from *unrolled small-L probe lowerings* and extrapolates linearly
in layer count, rather than trusting a single full-model parse.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# e.g.:  %ag = bf16[2,128]{1,0} all-gather(...), replica_groups={{0,1},...}
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce(?!-)|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_per_chip: float
    by_kind: Dict[str, float]

    def total(self) -> float:
        return self.bytes_per_chip


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = defaultdict(int)
    by_kind: Dict[str, float] = defaultdict(float)
    total = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if kind.endswith("-done"):
            continue  # paired with -start
        b = _shape_bytes(shape_str)
        gm = _GROUPS_RE.search(line)
        n = len(gm.group(1).split(",")) if gm else 2
        n = max(2, n)
        frac = (n - 1) / n
        if kind == "all-reduce":
            eff = 2.0 * b * frac
        elif kind == "collective-permute":
            eff = float(b)
        else:
            eff = b * frac
        counts[kind] += 1
        by_kind[kind] += eff
        total += eff
    return CollectiveStats(dict(counts), total, dict(by_kind))
