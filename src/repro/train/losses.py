"""LM losses: cross-entropy (+ z-loss) with optional MoE aux loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None,
                  z_loss_coef: float = 1e-4) -> dict:
    """logits: (B, S, V); labels: (B, S) int32; mask: (B, S) 1=count."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = z_loss_coef * jnp.square(lse)
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum((nll + zl) * mask) / denom
    return {
        "loss": loss,
        "nll": jnp.sum(nll * mask) / denom,
        "ppl_proxy": jnp.exp(jnp.clip(jnp.sum(nll * mask) / denom, 0, 20.0)),
        "tokens": denom,
    }
