from repro.train import checkpoint, data, losses, optimizer, train_loop
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import make_train_step, train

__all__ = ["checkpoint", "data", "losses", "optimizer", "train_loop",
           "DataConfig", "SyntheticLM", "AdamWConfig", "make_train_step",
           "train"]
