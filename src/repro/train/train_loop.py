"""Training loop: jitted train_step factory + driver."""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import transformer as T
from repro.train.losses import cross_entropy
from repro.train.optimizer import AdamWConfig, AdamWState, apply_updates, init_state


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    remat: bool = True) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        logits, aux = T.forward(params, cfg, batch, remat=remat)
        m = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
        return m["loss"] + aux, (m, aux)

    def train_step(params, opt_state: AdamWState, batch):
        (_, (m, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = apply_updates(opt_cfg, params, grads, opt_state)
        metrics = {**{k: v for k, v in m.items()}, "moe_aux": aux, **om}
        return params, opt_state, metrics

    return train_step


def train(cfg: ArchConfig, batches: Iterator[Dict], steps: int,
          opt_cfg: Optional[AdamWConfig] = None, seed: int = 0,
          log_every: int = 10, checkpoint_path: Optional[str] = None,
          checkpoint_every: int = 0) -> Dict:
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    key = jax.random.PRNGKey(seed)
    params = T.init_params(cfg, key)
    opt_state = init_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    history = []
    t0 = time.perf_counter()
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["elapsed_s"] = time.perf_counter() - t0
            history.append(m)
            print(f"step {step:5d} loss={m['loss']:.4f} nll={m['nll']:.4f} "
                  f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e}")
        if checkpoint_path and checkpoint_every and (step + 1) % checkpoint_every == 0:
            from repro.train.checkpoint import save
            save(checkpoint_path, params, opt_state, step=step + 1)
    return {"params": params, "opt_state": opt_state, "history": history}
