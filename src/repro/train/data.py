"""Synthetic-but-structured LM data pipeline.

Deterministic, seed-sharded token streams with learnable structure (a
Zipfian unigram base measure mixed with a repeated-ngram process), so a
~100M model trained a few hundred steps shows a *decreasing* loss — good
enough to validate the training substrate end-to-end without external
datasets (offline container).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.35       # P(copy an earlier ngram) — compressible
    ngram: int = 8


class SyntheticLM:
    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self._rng = np.random.default_rng(cfg.seed * 1009 + shard)
        # Zipfian base distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def _sequence(self) -> np.ndarray:
        c = self.cfg
        toks = np.empty(c.seq_len + 1, dtype=np.int32)
        i = 0
        while i < c.seq_len + 1:
            if i > c.ngram and self._rng.random() < c.repeat_p:
                # copy an earlier ngram (induction-head learnable)
                start = self._rng.integers(0, i - c.ngram)
                n = min(c.ngram, c.seq_len + 1 - i)
                toks[i:i + n] = toks[start:start + n]
                i += n
            else:
                toks[i] = self._rng.choice(c.vocab_size, p=self._p)
                i += 1
        return toks

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        c = self.cfg
        while True:
            seqs = np.stack([self._sequence() for _ in range(c.batch_size)])
            yield {
                "tokens": seqs[:, :-1],
                "labels": seqs[:, 1:].astype(np.int32),
            }
