"""Checkpointing: flattened-pytree .npz snapshots with step metadata.

No orbax dependency (offline container); supports async-style usage by
being cheap (np.savez of device-fetched arrays) and atomic (tmp+rename).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, params: Any, opt_state: Any = None, step: int = 0,
         extra: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    meta = json.dumps({"step": step, **(extra or {})})
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".", suffix=".npz")
    os.close(fd)
    np.savez(tmp, __meta__=np.frombuffer(meta.encode(), np.uint8), **payload)
    os.replace(tmp, path)


def restore(path: str, params_template: Any,
            opt_template: Any = None) -> Tuple[Any, Any, int]:
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        flat_p = {k[len("params/"):]: z[k] for k in z.files if k.startswith("params/")}
        flat_o = {k[len("opt/"):]: z[k] for k in z.files if k.startswith("opt/")}

    def fill(template, flat):
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = flat[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        return treedef.unflatten(leaves)

    params = fill(params_template, flat_p)
    opt = fill(opt_template, flat_o) if (opt_template is not None and flat_o) else None
    return params, opt, int(meta["step"])
