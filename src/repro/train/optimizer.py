"""AdamW with decoupled weight decay and global-norm clipping (pure JAX
pytree implementation — no optax dependency)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState
                  ) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/embedding-scales exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
