"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

--smoke trains the reduced variant on the host CPU (the runnable path in
this container); without it, the full config's distributed train step is
built with the production-mesh shardings (requires the pod, or the
dry-run harness for compile-only validation).
"""
from __future__ import annotations

import argparse
import dataclasses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on host CPU")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    from repro.config import get_arch, reduced
    from repro.train import AdamWConfig, DataConfig, SyntheticLM, train

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    batch_size=args.batch)
    res = train(cfg, SyntheticLM(dc).batches(), steps=args.steps,
                opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                                    total_steps=args.steps),
                checkpoint_path=args.checkpoint,
                checkpoint_every=50 if args.checkpoint else 0)
    h = res["history"]
    print(f"\nfinal: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} over "
          f"{args.steps} steps ({h[-1]['elapsed_s']:.1f}s)")


if __name__ == "__main__":
    main()
