# NOTE: do not import repro.launch.dryrun here — it sets XLA_FLAGS for 512
# host devices at import time, which must only happen in the dryrun entry
# point itself.
from repro.launch import mesh

__all__ = ["mesh"]
