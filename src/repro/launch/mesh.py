"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 256 chips as (data=16, model=16);
multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import math

import jax
import numpy as np

# v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the dry-run "
            "entry point must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    return jax.sharding.Mesh(np.array(devs[:n]).reshape(shape), axes)


def dp_axes(mesh: jax.sharding.Mesh):
    """The pure-data-parallel axes of a mesh (everything except 'model')."""
    names = tuple(n for n in mesh.axis_names if n != "model")
    return names if len(names) > 1 else names[0]


def axis_size(mesh: jax.sharding.Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n
