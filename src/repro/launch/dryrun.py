import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines, before any jax import: jax locks the
# device count at first init.  Smoke tests / benches do NOT import this
# module, so they see the single real CPU device.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.analysis import roofline as rl     # noqa: E402
from repro.config import (ALL_SHAPES, ArchConfig, StepKind, get_arch,  # noqa: E402
                          get_shape)
from repro.configs import ASSIGNED            # noqa: E402
from repro.distributed import sharding as sh  # noqa: E402
from repro.launch.mesh import axis_size, dp_axes, make_production_mesh  # noqa: E402
from repro.models import transformer as T     # noqa: E402
from repro.models.flops import model_flops    # noqa: E402
from repro.train.losses import cross_entropy  # noqa: E402
from repro.train.optimizer import AdamWConfig, apply_updates, init_state  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Config helpers


def with_layers(cfg: ArchConfig, n: int) -> ArchConfig:
    encdec = cfg.encdec
    if encdec is not None:
        encdec = dataclasses.replace(encdec, encoder_layers=n)
    return dataclasses.replace(cfg, n_layers=n, encdec=encdec)


def probe_layer_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """Two unrolled probe depths: one and two stack periods (Jamba's
    period is 8, homogeneous stacks use 2/4 for a stabler fit)."""
    (kinds, _), = T._stack_plan(cfg)
    period = len(kinds)
    if period == 1:
        return 2, 4
    return period, 2 * period


def serving_variant(cfg: ArchConfig, shape) -> Tuple[ArchConfig, str]:
    """long_500k needs sub-quadratic attention: SSM/hybrid/SWA archs run
    natively; pure full-attention archs get the documented sliding-window
    serving variant (window 4096)."""
    if shape.name != "long_500k" or cfg.supports_long_context_natively:
        return cfg, ""
    return (dataclasses.replace(cfg, sliding_window=4096),
            "swa-serving-variant(window=4096)")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)


def input_specs(cfg: ArchConfig, shape) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.step == StepKind.DECODE:
        batch["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        return batch
    if cfg.frontend is not None and cfg.encdec is None:     # VLM
        batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.frontend.embed_dim), dt)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.encdec is not None:                               # audio enc-dec
        Tenc = cfg.encdec.max_source_positions
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, Tenc, cfg.frontend.embed_dim if cfg.frontend else cfg.d_model), dt)
    if shape.step == StepKind.TRAIN:
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return batch


def cache_template(cfg: ArchConfig, batch: int, seq_len: int):
    dt = jnp.dtype(cfg.dtype)

    def build():
        layers = T.init_caches(None, cfg, batch, seq_len)
        out = {"layers": layers}
        if cfg.encdec is not None:
            (kinds, n_groups), = T._stack_plan(cfg)
            Tenc = cfg.encdec.max_source_positions
            hd = cfg.resolved_head_dim
            kv_shape = (n_groups, batch, Tenc, cfg.n_kv_heads, hd)
            out["cross_kv"] = tuple(
                (jnp.zeros(kv_shape, dt), jnp.zeros(kv_shape, dt))
                for _ in kinds)
        return out

    return jax.eval_shape(build)


# ---------------------------------------------------------------------------
# Step builders


def build_step(cfg: ArchConfig, shape, mesh, policy: sh.Policy,
               unroll: bool = False):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    B, S = shape.global_batch, shape.seq_len
    training = shape.step == StepKind.TRAIN
    pspecs = sh.param_specs(cfg, mesh, training=training, policy=policy)
    act = sh.act_spec(cfg, mesh, B, policy)
    act_shd = NamedSharding(mesh, act)
    bspecs = sh.batch_specs(cfg, shape, mesh)
    params_tpl = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                jax.random.PRNGKey(0))
    batch_tpl = input_specs(cfg, shape)
    dp = dp_axes(mesh)
    b_ax = dp if shape.global_batch % axis_size(mesh, dp) == 0 else None

    def ns(tree):
        return sh.named(mesh, tree)

    attn_impl = "chunked" if policy.chunked_attention else "dense"
    moe_shd = None
    if policy.shard_moe_dispatch and cfg.moe is not None:
        moe_shd = NamedSharding(mesh, P(None, dp, "model" if cfg.d_model % axis_size(mesh, "model") == 0 else None))
    moe_groups = 0
    moe_gshd = None
    if policy.moe_local_dispatch and cfg.moe is not None:
        moe_groups = axis_size(mesh, dp)
        d_ax = "model" if cfg.d_model % axis_size(mesh, "model") == 0 else None
        moe_gshd = {
            "x": NamedSharding(mesh, P(dp, None, d_ax)),
            "dispatch": NamedSharding(mesh, P(dp, None, None, d_ax)),
        }
    if training:
        opt_cfg = AdamWConfig()
        opt_tpl = jax.eval_shape(init_state, params_tpl)
        opt_specs = type(opt_tpl)(step=P(), mu=pspecs, nu=pspecs)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                logits, aux = T.forward(p, cfg, batch, remat=True,
                                        act_sharding=act_shd, unroll=unroll,
                                        attn_impl=attn_impl,
                                        moe_dispatch_sharding=moe_shd,
                                        moe_local_groups=moe_groups,
                                        moe_group_sharding=moe_gshd)
                m = cross_entropy(logits, batch["labels"])
                return m["loss"] + aux, m["nll"]

            (_, nll), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            params, opt_state, om = apply_updates(opt_cfg, params, grads, opt_state)
            return params, opt_state, nll

        return (train_step, (params_tpl, opt_tpl, batch_tpl),
                (ns(pspecs), ns(opt_specs), ns(bspecs)),
                (ns(pspecs), ns(opt_specs), NamedSharding(mesh, P())))

    if shape.step == StepKind.PREFILL:
        def prefill_step(params, batch):
            logits, caches = T.prefill(params, cfg, batch, seq_len=S,
                                       act_sharding=act_shd, unroll=unroll,
                                       attn_impl=attn_impl,
                                       moe_dispatch_sharding=moe_shd)
            return logits, caches

        cache_tpl = jax.eval_shape(prefill_step, params_tpl, batch_tpl)[1]
        cspecs = sh.cache_specs_for(cache_tpl, cfg, mesh, B, policy)
        logits_spec = P(b_ax, None, None)
        return (prefill_step, (params_tpl, batch_tpl),
                (ns(pspecs), ns(bspecs)),
                (NamedSharding(mesh, logits_spec), ns(cspecs)))

    # DECODE: one token against a KV cache of seq_len
    cache_tpl = cache_template(cfg, B, S)
    cspecs = sh.cache_specs_for(cache_tpl, cfg, mesh, B, policy)

    cache_update = "select" if policy.select_cache_update else "dus"

    def decode_fn(params, tokens, pos, caches):
        logits, new_caches = T.decode_step(
            params, cfg, tokens, pos, caches, act_sharding=act_shd,
            unroll=unroll, cache_update=cache_update,
            mixed_precision=policy.attn_mixed_precision)
        return logits, new_caches

    tok_tpl = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_tpl = jax.ShapeDtypeStruct((), jnp.int32)
    return (decode_fn, (params_tpl, tok_tpl, pos_tpl, cache_tpl),
            (ns(pspecs), NamedSharding(mesh, P(b_ax, None)),
             NamedSharding(mesh, P()), ns(cspecs)),
            (NamedSharding(mesh, P(b_ax, None, None)), ns(cspecs)))


# ---------------------------------------------------------------------------
# Lower + compile + analyse


def lower_and_compile(cfg: ArchConfig, shape, mesh, policy: sh.Policy,
                      unroll: bool = False):
    fn, args, in_sh, out_sh = build_step(cfg, shape, mesh, policy, unroll)
    # buffer donation: train_step updates (params, opt) in place; serve_step
    # updates the KV cache in place — without this the dry-run double-counts
    # the dominant buffers.
    if shape.step == StepKind.TRAIN:
        donate = (0, 1)
    elif shape.step == StepKind.DECODE:
        donate = (3,)
    else:
        donate = ()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def memory_summary(compiled) -> Dict[str, float]:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "peak_memory_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = float(v)
        # peak = live-buffer high-water mark per device (what must fit HBM)
        out["total_gb"] = out.get("peak_memory_in_bytes", 0.0) / 1e9
    except Exception as e:  # pragma: no cover
        out["error"] = str(e)
    return out


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             probes: bool = True, policy: Optional[sh.Policy] = None,
             verbose: bool = True) -> Dict:
    """Dry-run one (architecture x input shape x mesh): lower + compile the
    full model, then (optionally) the two unrolled roofline probes."""
    policy = policy or sh.Policy()
    shape = get_shape(shape_name)
    cfg0 = get_arch(arch)
    cfg, variant_note = serving_variant(cfg0, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.size
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": chips, "note": variant_note,
                 "policy": dataclasses.asdict(policy)}
    # simlint: allow[wall-clock] compile_s measures real XLA compile time
    t0 = time.time()
    lowered, compiled = lower_and_compile(cfg, shape, mesh, policy)
    # simlint: allow[wall-clock] compile_s measures real XLA compile time
    rec["compile_s"] = time.time() - t0
    rec["memory"] = memory_summary(compiled)
    ca = compiled.cost_analysis()
    rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                            if isinstance(v, (int, float))
                            and k in ("flops", "bytes accessed",
                                      "utilization operand 0", "optimal_seconds")}
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] compiled in "
              f"{rec['compile_s']:.1f}s  mem={rec['memory'].get('total_gb', float('nan')):.2f}GB/dev "
              f"flops/dev={rec['cost_analysis'].get('flops', 0):.3e}")

    if probes:
        la, lb = probe_layer_counts(cfg)
        pts = []
        for l in (la, lb):
            pcfg = with_layers(cfg, l)
            _, pc = lower_and_compile(pcfg, shape, mesh, policy, unroll=True)
            pts.append(rl.probe_from_compiled(l, pc))
            if verbose:
                print(f"    probe L={l}: flops={pts[-1].flops:.3e} "
                      f"coll={pts[-1].coll_bytes:.3e}B")
        totals = rl.extrapolate(pts[0], pts[1], cfg.n_layers)
        mf = model_flops(cfg, shape)
        roof = rl.build_roofline(
            arch, shape_name, mesh_name, chips, totals, mf["model_flops"],
            memory_per_chip_gb=rec["memory"].get("total_gb"),
            notes=variant_note)
        rec["probes"] = [dataclasses.asdict(p) for p in pts]
        rec["roofline"] = dataclasses.asdict(roof)
        if verbose:
            r = roof
            print(f"    roofline: compute={r.compute_s*1e3:.2f}ms "
                  f"memory={r.memory_s*1e3:.2f}ms coll={r.collective_s*1e3:.2f}ms "
                  f"-> {r.bottleneck}-bound, useful={r.useful_ratio:.2f}")
    return rec


def save_record(rec: Dict, out_dir: str = RESULTS_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return path


OPTIMIZED_POLICY = sh.Policy(chunked_attention=True, moe_local_dispatch=True,
                             select_cache_update=True,
                             attn_mixed_precision=True)


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="use the SSPerf-winning policy instead of baseline")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    policy = OPTIMIZED_POLICY if args.optimized else None
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    # probes only make sense for the (single-pod) roofline
                    rec = run_pair(arch, shape, multi_pod=mp,
                                   probes=not args.no_probes and not mp,
                                   policy=policy)
                    save_record(rec, args.out)
                except Exception as e:
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"FAILED [{arch} x {shape} x mp={mp}]: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
