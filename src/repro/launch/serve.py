"""Serving launcher: deploy a model endpoint behind the junctiond FaaS
runtime and drive batched requests through the gateway->provider->instance
path.  ``python -m repro.launch.serve --arch <id> [--backend junctiond]``.
"""
from __future__ import annotations

import argparse
import dataclasses



def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--backend", default="junctiond",
                    choices=["junctiond", "containerd"])
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--batch-slots", type=int, default=4)
    args = ap.parse_args()

    from repro.config import get_arch, reduced
    from repro.core import (FaasdRuntime, FunctionSpec, Simulator,
                            run_sequential)
    from repro.serving import ServingEngine

    cfg = dataclasses.replace(reduced(get_arch(args.arch)), dtype="float32")
    print(f"deploying {args.arch} (reduced, CPU) behind {args.backend} ...")
    eng = ServingEngine(cfg, batch_slots=args.batch_slots, max_seq_len=64)
    # measure the real decode step on this host -> the function body cost
    prompts = [[1, 2, 3, 4]] * args.batch_slots
    eng.generate(prompts, max_new_tokens=4)
    svc_us = eng.mean_decode_step_us()
    print(f"measured decode step: {svc_us:.0f} us/batch "
          f"({args.batch_slots} slots)")

    sim = Simulator(seed=0)
    rt = FaasdRuntime(sim, backend=args.backend)
    rt.deploy_blocking(FunctionSpec(name=args.arch, work_us=svc_us,
                                    payload_bytes=2048, response_bytes=4096))
    summary = run_sequential(rt, args.arch, n=args.requests)
    print(f"{args.requests} invocations through the {args.backend} runtime: "
          f"median={summary.median_ms:.3f} ms  p99={summary.p99_ms:.3f} ms")
    overhead = summary.median_ms - svc_us * 1e-3
    print(f"FaaS runtime overhead at median: {overhead:.3f} ms "
          f"({100 * overhead / summary.median_ms:.1f}% of e2e)")


if __name__ == "__main__":
    main()
