import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver.

Runs one (arch x shape) pair under a sequence of sharding/impl policy
variants, records the roofline terms per variant, and prints the
hypothesis -> change -> before/after trail.  Used for the three chosen
pairs (and anything else you point it at):

    PYTHONPATH=src python -m repro.launch.perf --arch deepseek-67b --shape prefill_32k
"""

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
from typing import Dict, List, Optional, Tuple  # noqa: E402

from repro.distributed import sharding as sh    # noqa: E402
from repro.launch.dryrun import RESULTS_DIR, run_pair  # noqa: E402

PERF_DIR = os.path.join(os.path.dirname(RESULTS_DIR), "perf")


def variants_for(shape_name: str, arch: str) -> List[Tuple[str, sh.Policy, str]]:
    """(variant-name, policy, hypothesis) sequences per shape kind."""
    base = sh.Policy()
    out = [("baseline", base, "paper-faithful baseline policy")]
    if shape_name in ("train_4k", "prefill_32k"):
        out.append((
            "chunked_attn",
            dataclasses.replace(base, chunked_attention=True),
            "score matrix (S*T*H fp32) dominates HBM traffic; chunked "
            "online-softmax keeps it in registers/VMEM -> memory term "
            "should drop toward the weight+activation floor"))
        out.append((
            "chunked_attn+ep",
            dataclasses.replace(base, chunked_attention=True,
                                moe_expert_parallel=True),
            "expert weights are TP-sharded on d_ff; sharding the EXPERT dim "
            "instead turns per-layer weight all-gathers into token "
            "all-to-alls (top2/E of the volume) -> collective term drops"))
        out.append((
            "chunked+moe_shard",
            dataclasses.replace(base, chunked_attention=True,
                                shard_moe_dispatch=True),
            "the L-probes show ~100GB/chip of collectives per step: GSPMD "
            "replicates the (E, C, d) MoE dispatch buffer across the data "
            "axis; constraining C over 'data' and d over 'model' keeps the "
            "scatter local -> collective term should drop by the dispatch "
            "share"))
        out.append((
            "chunked+moe_local",
            dataclasses.replace(base, chunked_attention=True,
                                moe_local_dispatch=True),
            "global top-k dispatch needs a cumsum over ALL tokens (rank) "
            "and a combine-gather that both cross data shards — the probe "
            "shows them as the dominant all-gathers; per-shard LOCAL "
            "dispatch (the production design) keeps every MoE tensor's "
            "leading dim on the data axis -> those collectives vanish"))
        out.append((
            "chunked_attn+no_fsdp",
            dataclasses.replace(base, chunked_attention=True, fsdp=False),
            "FSDP all-gathers weights every step; with 256-way sharding the "
            "gather may dominate collectives — trading memory for traffic "
            "should show in the collective term (expected REGRESSION in "
            "memory capacity; test quantifies the tradeoff)"))
    else:  # decode shapes
        out.append((
            "select_cache_update",
            dataclasses.replace(base, select_cache_update=True),
            "dynamic_update_slice at a dynamic slot forces SPMD to "
            "REPLICATE the seq-sharded KV cache every step (the involuntary "
            "full-rematerialization warnings) -> iota==slot masked select "
            "is elementwise and layout-preserving; memory term should fall "
            "to weights+2x cache traffic"))
        sel = dataclasses.replace(base, select_cache_update=True)
        out.append((
            "sel+mixed_prec",
            dataclasses.replace(sel, attn_mixed_precision=True),
            "the decode profile shows `convert` dominating HBM bytes: the "
            "reference attention materialises fp32 copies of the bf16 KV "
            "cache; bf16 dots with an fp32 accumulator (preferred_element_"
            "type — what the MXU does natively) should cut cache traffic "
            "~3x and the memory term with it"))
        out.append((
            "sel+replicated_kv_seq",
            dataclasses.replace(sel, seq_sharded_cache=False),
            "seq-sharded KV makes every decode step reduce partial attention "
            "across 'model'; replicating the cache removes that collective "
            "at a memory cost — quantify the tradeoff (composed on the "
            "select fix)"))
        out.append((
            "sel+expert_parallel",
            dataclasses.replace(sel, moe_expert_parallel=True),
            "at B<=128 decode, capacity dispatch computes all E experts; "
            "expert-parallel sharding moves tokens (all-to-all) instead of "
            "computing idle experts -> compute term drops ~E/topk "
            "(composed on the select fix)"))
        out.append((
            "sel+no_act_shard",
            dataclasses.replace(sel, act_model_sharded=False),
            "per-block activation resharding at B tokens is latency-bound "
            "collectives; replicated activations should cut the collective "
            "term for single-token decode (composed on the select fix)"))
    return out


def hillclimb(arch: str, shape: str, *, multi_pod: bool = False,
              variants: Optional[List[str]] = None) -> List[Dict]:
    os.makedirs(PERF_DIR, exist_ok=True)
    results = []
    for name, policy, hypothesis in variants_for(shape, arch):
        if variants and name not in variants and name != "baseline":
            continue
        print(f"\n=== {arch} x {shape} :: {name} ===")
        print(f"hypothesis: {hypothesis}")
        try:
            rec = run_pair(arch, shape, multi_pod=multi_pod, probes=True,
                           policy=policy)
        except Exception as e:
            print(f"variant FAILED: {e!r}")
            results.append({"variant": name, "error": repr(e),
                            "hypothesis": hypothesis})
            continue
        rec["variant"] = name
        rec["hypothesis"] = hypothesis
        path = os.path.join(PERF_DIR, f"{arch}__{shape}__{name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
        results.append(rec)
    _summarise(arch, shape, results)
    return results


def _summarise(arch, shape, results):
    print(f"\n### summary {arch} x {shape}")
    print(f"{'variant':24s} {'compute_ms':>10} {'memory_ms':>10} {'coll_ms':>9} "
          f"{'step_ms':>9} {'bound':>10} {'peakGB':>7}")
    base_step = None
    for r in results:
        roof = r.get("roofline")
        if not roof:
            print(f"{r['variant']:24s}  FAILED: {r.get('error')}")
            continue
        step = roof["step_time_s"] * 1e3
        if r["variant"] == "baseline":
            base_step = step
        gain = f" ({base_step / step:.2f}x)" if base_step and r["variant"] != "baseline" else ""
        print(f"{r['variant']:24s} {roof['compute_s']*1e3:10.2f} "
              f"{roof['memory_s']*1e3:10.2f} {roof['collective_s']*1e3:9.2f} "
              f"{step:9.2f}{gain} {roof['bottleneck']:>10} "
              f"{r['memory'].get('total_gb', float('nan')):7.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    hillclimb(args.arch, args.shape, multi_pod=args.multi_pod,
              variants=args.variant)


if __name__ == "__main__":
    main()
