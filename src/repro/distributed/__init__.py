from repro.distributed import sharding
from repro.distributed.sharding import Policy

__all__ = ["sharding", "Policy"]
