"""Sharding policies: parameter / optimizer / activation / cache
PartitionSpecs per (architecture x input shape x mesh).

A name-based rule engine with divisibility fallbacks: a dimension is only
sharded when it divides the axis size, otherwise the next candidate (or
replication) is used — this is what lets one policy cover ten
architectures (e.g. seamless's vocab 256206 is not 16-divisible, so its
lm_head falls back to d-sharding).

Baseline policy (the §Perf hillclimb iterates on this):
* weights: TP over 'model' on the "wide" dim; FSDP over 'data' on the
  other dim for training;
* activations between blocks: (dp, None, 'model');
* KV caches: batch over dp when divisible, sequence over 'model'
  (sequence-sharded decode — kv_heads=8 < model=16 makes head-sharding
  impossible for most assigned archs);
* SSM states: batch over dp, d_inner/heads over 'model'.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig, InputShape, StepKind
from repro.launch.mesh import axis_size, dp_axes

# weight matrices whose LAST dim is the "wide"/output dim -> TP on last
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "cm_wk", "wg", "wr",
                 "in_proj", "x_proj", "dt_proj_w", "frontend_proj", "lm_head"}
# matrices whose second-to-last dim is the contracted/wide dim -> TP on -2
_ROW_PARALLEL = {"wo", "w_down", "cm_wv", "out_proj"}
# per-channel vectors over d_inner / heads
_DI_VECTORS = {"conv_b", "D", "dt_proj_b"}


def _divides(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


@dataclasses.dataclass(frozen=True)
class Policy:
    """Tunable knobs — §Perf hillclimbing flips these."""
    fsdp: bool = True                 # shard weights over dp for training
    act_model_sharded: bool = True    # activations d-sharded between blocks
    seq_sharded_cache: bool = True    # KV seq over 'model' (vs replicated)
    vocab_sharded_logits: bool = True
    chunked_attention: bool = False   # flash-style XLA attention (SSPerf)
    moe_expert_parallel: bool = False # shard the expert dim over 'model'
    select_cache_update: bool = False # iota-select KV write (SPMD-friendly)
    attn_mixed_precision: bool = False # bf16 dots, f32 accum (MXU-native)
    shard_moe_dispatch: bool = False  # constrain (E,C,d) dispatch over dp
    moe_local_dispatch: bool = False  # per-data-shard routing (production)


def param_specs(cfg: ArchConfig, mesh: Mesh, *, training: bool,
                policy: Policy = Policy()):
    """PartitionSpec pytree matching transformer.init_params(cfg)."""
    from repro.models import transformer as T
    shapes = jax.eval_shape(lambda k: T.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    model_n = axis_size(mesh, "model")
    dp = dp_axes(mesh)
    dp_n = axis_size(mesh, dp)
    use_fsdp = policy.fsdp and training

    def base_rule(name: str, shape: Tuple[int, ...]) -> list:
        """Spec for the *unstacked* trailing dims of a leaf."""
        nd = len(shape)

        def fs(dim_size):  # fsdp candidate on a dim
            return dp if (use_fsdp and _divides(dim_size, dp_n)) else None

        if name == "embed":
            spec = [None, None]
            if _divides(shape[1], model_n):
                spec[1] = "model"
            if use_fsdp and _divides(shape[0], dp_n):
                spec[0] = dp
            return spec
        if name == "lm_head":
            if policy.vocab_sharded_logits and _divides(shape[-1], model_n):
                return [fs(shape[0]), "model"]
            if _divides(shape[0], model_n):
                return ["model", fs(shape[1])]
            return [None, None]
        if name == "A_log":      # (di, ds)
            return ["model" if _divides(shape[0], model_n) else None, None]
        if name in _DI_VECTORS:  # (di,)
            return ["model" if _divides(shape[-1], model_n) else None]
        if name == "conv_w":     # (d_conv, di)
            return [None, "model" if _divides(shape[-1], model_n) else None]
        if name == "bonus_u":    # (H, hd)
            return ["model" if _divides(shape[0], model_n) else None, None]
        if name == "router":
            return [None] * nd
        if name in _COL_PARALLEL:
            spec = [None] * nd
            if _divides(shape[-1], model_n):
                spec[-1] = "model"
                if use_fsdp and _divides(shape[-2], dp_n):
                    spec[-2] = dp
            elif _divides(shape[-2], model_n):
                spec[-2] = "model"
            return spec
        if name in _ROW_PARALLEL:
            spec = [None] * nd
            if _divides(shape[-2], model_n):
                spec[-2] = "model"
                if use_fsdp and _divides(shape[-1], dp_n):
                    spec[-1] = dp
            return spec
        # norms, mu_*, decay_base, ln_x, scalars: replicate
        return [None] * nd

    def rule(path, leaf) -> P:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        # block params are stacked over the scan (group) dim — never sharded
        stacked = any(n in ("blocks", "enc_blocks") for n in names)
        shape = leaf.shape[1:] if stacked else leaf.shape
        # expert parallelism: shard the expert dim instead of TP-on-f
        if (policy.moe_expert_parallel and "moe" in names
                and name in ("w_gate", "w_up", "w_down")
                and shape and _divides(shape[0], model_n)):
            spec = ["model"] + [None] * (len(shape) - 1)
        else:
            spec = base_rule(name, shape) if shape else []
        if stacked:
            spec = [None] + spec
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = [rule(path, leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def act_spec(cfg: ArchConfig, mesh: Mesh, batch: int,
             policy: Policy = Policy()) -> Optional[P]:
    """Between-block activation sharding (B, S, d)."""
    model_n = axis_size(mesh, "model")
    dp = dp_axes(mesh)
    dp_n = axis_size(mesh, dp)
    b = dp if _divides(batch, dp_n) else None
    d = "model" if (policy.act_model_sharded and _divides(cfg.d_model, model_n)) else None
    return P(b, None, d)


def batch_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> dict:
    """PartitionSpecs for the input batch dict (matches input_specs)."""
    dp = dp_axes(mesh)
    dp_n = axis_size(mesh, dp)
    b = dp if _divides(shape.global_batch, dp_n) else None
    specs = {}
    if cfg.frontend is not None and cfg.encdec is None:
        specs["embeds"] = P(b, None, None)
    else:
        specs["tokens"] = P(b, None)
    if cfg.encdec is not None:
        specs["enc_embeds"] = P(b, None, None)
    if shape.step == StepKind.TRAIN:
        specs["labels"] = P(b, None)
    return specs


def _dp_list(dp) -> list:
    return [dp] if isinstance(dp, str) else list(dp)


def cache_specs_for(tree, cfg: ArchConfig, mesh: Mesh, batch: int,
                    policy: Policy = Policy()):
    """PartitionSpec pytree for a cache pytree (the eval_shape of
    ``prefill``'s cache output: {'layers': ..., 'cross_kv': ...}).
    Cache leaves carry a leading n_groups scan dim (never sharded)."""
    model_n = axis_size(mesh, "model")
    dp = dp_axes(mesh)
    dp_n = axis_size(mesh, dp)
    b = dp if _divides(batch, dp_n) else None

    def seq_spec(cap: int):
        """Axes for the sequence dim: dp lands here when the batch can't
        absorb it (long-context batch=1), plus 'model' when enabled."""
        axes = []
        if b is None:
            axes.extend(_dp_list(dp))
        if policy.seq_sharded_cache:
            axes.append("model")
        while axes:
            n = 1
            for a in axes:
                n *= axis_size(mesh, a)
            if _divides(cap, n):
                break
            axes.pop()            # drop minor axes until it divides
        if not axes:
            return None
        return tuple(axes) if len(axes) > 1 else axes[0]

    def rule(path, leaf) -> P:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        shape = leaf.shape
        if "cross_kv" in names:   # (g, B, T, Hkv, hd)
            return P(None, b, seq_spec(shape[2]), None, None)
        if name == "enc_mask":
            return P(b, None)
        if name in ("k", "v"):    # (g, B, cap, Hkv, hd)
            return P(None, b, seq_spec(shape[2]), None, None)
        if name == "h":           # mamba (g, B, di, ds)
            return P(None, b, "model" if _divides(shape[2], model_n) else None, None)
        if name == "conv":        # (g, B, dconv-1, di)
            return P(None, b, None, "model" if _divides(shape[3], model_n) else None)
        if name == "wkv":         # (g, B, H, hd, hd)
            return P(None, b, "model" if _divides(shape[2], model_n) else None, None, None)
        if name in ("shift_tm", "shift_cm"):   # (g, B, 1, d)
            return P(None, b, None, "model" if _divides(shape[3], model_n) else None)
        return P(*([None] * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return jax.tree_util.tree_unflatten(treedef, [rule(p, l) for p, l in flat])


def named(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
