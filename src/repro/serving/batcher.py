"""Continuous batcher: admission queue + iteration-level scheduling.

Decode steps run at fixed batch width (the compiled shape); finished or
empty slots are masked.  New requests join at the next iteration boundary
(Orca-style iteration-level scheduling), which is what keeps the paper's
serving story honest when the "function" is a model endpoint.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

from repro.serving.kvcache import PagedKVManager


@dataclasses.dataclass
class Request:
    req_id: int
    prompt_tokens: list
    max_new_tokens: int
    arrived_at: float = 0.0
    seq_id: Optional[int] = None
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def prompt_len(self) -> int:
        return len(self.prompt_tokens)


class ContinuousBatcher:
    def __init__(self, kv: PagedKVManager, max_batch: int):
        self.kv = kv
        self.max_batch = max_batch
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}    # slot -> request
        self._next_req = 0

    def submit(self, prompt_tokens: list, max_new_tokens: int,
               now: float = 0.0) -> Request:
        r = Request(self._next_req, list(prompt_tokens), max_new_tokens,
                    arrived_at=now)
        self._next_req += 1
        self.waiting.append(r)
        return r

    def admit_ready(self) -> List[Request]:
        """Move waiting requests into free slots (to be prefilled)."""
        admitted = []
        while (self.waiting and len(self.running) < self.max_batch
               and self.kv.can_admit()):
            r = self.waiting.popleft()
            st = self.kv.admit()
            r.seq_id = st.seq_id
            self.running[st.slot] = r
            self.kv.advance(st.seq_id, r.prompt_len)
            admitted.append(r)
        return admitted

    def record_token(self, slot: int, token: int) -> None:
        r = self.running[slot]
        r.generated.append(int(token))
        self.kv.advance(r.seq_id, 1)
        if len(r.generated) >= r.max_new_tokens:
            self.finish(slot)

    def finish(self, slot: int) -> None:
        r = self.running.pop(slot)
        r.done = True
        self.kv.release(r.seq_id)

    @property
    def active_slots(self) -> List[int]:
        return sorted(self.running)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
