"""Paged KV-cache manager (vLLM-style pages, host-side bookkeeping).

The device-side caches are the stacked per-layer tensors built by
``transformer.init_caches``; this manager owns the *slot* dimension:
which sequence occupies which batch slot, page accounting for admission
control, and ring-buffer semantics for sliding-window architectures.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.config import ArchConfig
from repro.models.attention import kv_cache_capacity


@dataclasses.dataclass
class SeqState:
    seq_id: int
    slot: int
    length: int = 0          # tokens written so far
    max_len: int = 0


class PagedKVManager:
    """Fixed-slot cache pool with page-granular accounting."""

    def __init__(self, cfg: ArchConfig, n_slots: int, max_seq_len: int,
                 page_tokens: int = 128):
        self.cfg = cfg
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.page_tokens = page_tokens
        cap = kv_cache_capacity(cfg, max_seq_len) if cfg.n_kv_heads else 0
        self.pages_per_slot = max(1, -(-cap // page_tokens))
        self.total_pages = self.pages_per_slot * n_slots
        self.free_slots: List[int] = list(range(n_slots))
        self.seqs: Dict[int, SeqState] = {}
        self._next_id = 0

    # -- admission -----------------------------------------------------
    def can_admit(self) -> bool:
        return bool(self.free_slots)

    def admit(self, max_len: Optional[int] = None) -> SeqState:
        if not self.free_slots:
            raise RuntimeError("KV cache full: no free slots")
        slot = self.free_slots.pop(0)
        st = SeqState(seq_id=self._next_id, slot=slot,
                      max_len=max_len or self.max_seq_len)
        self._next_id += 1
        self.seqs[st.seq_id] = st
        return st

    def release(self, seq_id: int) -> None:
        st = self.seqs.pop(seq_id)
        self.free_slots.append(st.slot)
        self.free_slots.sort()

    def advance(self, seq_id: int, n_tokens: int = 1) -> None:
        st = self.seqs[seq_id]
        st.length += n_tokens
        if st.length > st.max_len:
            raise RuntimeError(f"seq {seq_id} exceeded max_len {st.max_len}")

    # -- accounting -----------------------------------------------------
    @property
    def used_pages(self) -> int:
        per = self.page_tokens
        return sum(min(-(-s.length // per), self.pages_per_slot)
                   for s in self.seqs.values())

    def utilization(self) -> float:
        return self.used_pages / max(1, self.total_pages)

    def bytes_per_slot(self) -> int:
        cfg = self.cfg
        if not cfg.n_kv_heads:
            return 0
        cap = kv_cache_capacity(cfg, self.max_seq_len)
        hd = cfg.resolved_head_dim
        n_attn = sum(1 for k in cfg.block_kinds() if k.value.startswith("attn"))
        itemsize = 2 if cfg.dtype == "bfloat16" else 4
        return 2 * cap * cfg.n_kv_heads * hd * n_attn * itemsize
