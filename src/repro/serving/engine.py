"""Serving engine: compiled prefill/decode steps + generation loop.

This is the "model endpoint" a junctiond function deploys.  It measures
its own per-step wall time so the FaaS layer can use measured service
times (CPU, reduced models) or roofline-derived analytic ones (full
models on the production mesh).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import transformer as T
from repro.serving.batcher import ContinuousBatcher
from repro.serving.kvcache import PagedKVManager
from repro.serving.sampling import sample


class ServingEngine:
    def __init__(self, cfg: ArchConfig, *, batch_slots: int = 4,
                 max_seq_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.max_seq_len = max_seq_len
        key = jax.random.PRNGKey(seed)
        self.params = T.init_params(cfg, key)
        self.kv = PagedKVManager(cfg, batch_slots, max_seq_len)
        self.batcher = ContinuousBatcher(self.kv, batch_slots)
        self.caches = None
        self._rng = jax.random.PRNGKey(seed + 1)
        self.step_times_s: List[float] = []

        @jax.jit
        def _prefill(params, tokens):
            logits, caches = T.prefill(params, cfg, {"tokens": tokens},
                                       seq_len=max_seq_len)
            return logits, caches

        @jax.jit
        def _decode(params, tokens, pos, caches):
            return T.decode_step(params, cfg, tokens, pos, caches)

        self._prefill = _prefill
        self._decode = _decode

    # ------------------------------------------------------------------
    def generate(self, prompts: List[List[int]], max_new_tokens: int = 8,
                 temperature: float = 0.0) -> List[List[int]]:
        """Batched greedy/temperature generation (all prompts same length
        for the compiled shape; the batcher handles slot lifecycle)."""
        reqs = [self.batcher.submit(p, max_new_tokens) for p in prompts]
        self.batcher.admit_ready()
        plen = len(prompts[0])
        assert all(len(p) == plen for p in prompts), "batch requires equal prompt lengths"
        tokens = jnp.asarray(prompts, jnp.int32)
        t0 = time.perf_counter()
        logits, caches = self._prefill(self.params, tokens)
        logits.block_until_ready()
        self.step_times_s.append(time.perf_counter() - t0)
        pos = plen
        self._rng, k = jax.random.split(self._rng)
        next_tok = sample(logits, k, temperature)
        for slot, r in list(self.batcher.running.items()):
            self.batcher.record_token(slot, int(next_tok[slot]))
        while any(not r.done for r in reqs) and pos < self.max_seq_len - 1:
            t0 = time.perf_counter()
            logits, caches = self._decode(self.params, next_tok[:, None],
                                          jnp.int32(pos), caches)
            logits.block_until_ready()
            self.step_times_s.append(time.perf_counter() - t0)
            self._rng, k = jax.random.split(self._rng)
            next_tok = sample(logits, k, temperature)
            pos += 1
            for slot in list(self.batcher.running):
                self.batcher.record_token(slot, int(next_tok[slot]))
            if not self.batcher.running:
                break
        return [r.generated for r in reqs]

    # ------------------------------------------------------------------
    def mean_decode_step_us(self) -> float:
        if len(self.step_times_s) <= 1:
            return float("nan")
        return 1e6 * sum(self.step_times_s[1:]) / len(self.step_times_s[1:])
