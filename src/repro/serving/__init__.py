from repro.serving import batcher, engine, kvcache, sampling
from repro.serving.batcher import ContinuousBatcher, Request
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import PagedKVManager

__all__ = ["batcher", "engine", "kvcache", "sampling", "ContinuousBatcher",
           "Request", "ServingEngine", "PagedKVManager"]
