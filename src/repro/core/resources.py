"""Host CPU core pool with a scheduling-thrash model.

``consume`` acquires one core for ``cpu_time`` seconds (FIFO).  The
effective occupancy is scaled by a thrash multiplier that grows with the
runnable backlog — modelling cache pollution, migrations and context
switching of kernel CFS under load (cf. Caladan).  Junction's
run-to-completion scheduling sets a near-1 cap.
"""
from __future__ import annotations

from typing import Generator, Optional

from repro.core.latency import RuntimeCosts
from repro.core.simulator import Event, Simulator


class CorePool:
    def __init__(self, sim: Simulator, n_cores: int, runtime: RuntimeCosts):
        self.sim = sim
        self.n_cores = n_cores
        self.runtime = runtime
        self.busy = 0
        self._waiters: list = []
        # accounting
        self.busy_time = 0.0
        self.served = 0

    # -- inspection ------------------------------------------------------
    @property
    def backlog(self) -> int:
        return len(self._waiters)

    def thrash(self) -> float:
        r = self.runtime
        x = self.backlog / max(1, self.n_cores)
        return min(r.thrash_cap, 1.0 + r.thrash_coeff * x)

    def utilization(self, horizon: float) -> float:
        return self.busy_time / (horizon * self.n_cores) if horizon > 0 else 0.0

    # -- usage -------------------------------------------------------
    def consume(self, cpu_time: float) -> Generator:
        """Process-style: yield from pool.consume(t)."""
        ev: Optional[Event] = None
        if self.busy >= self.n_cores:
            ev = self.sim.event()
            self._waiters.append(ev)
            yield ev
        self.busy += 1
        eff = cpu_time * self.thrash()
        yield self.sim.timeout(eff)
        self.busy -= 1
        self.busy_time += eff
        self.served += 1
        if self._waiters and self.busy < self.n_cores:
            self._waiters.pop(0).succeed()

    def remove_cores(self, n: int) -> None:
        """Dedicate cores elsewhere (e.g. per-instance polling)."""
        self.n_cores = max(0, self.n_cores - n)

    def add_cores(self, n: int) -> None:
        self.n_cores += n
