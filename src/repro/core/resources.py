"""Host CPU core pool with a scheduling-thrash model.

``consume`` acquires one core for ``cpu_time`` seconds (FIFO).  The
effective occupancy is scaled by a thrash multiplier that grows with the
runnable backlog — modelling cache pollution, migrations and context
switching of kernel CFS under load (cf. Caladan).  Junction's
run-to-completion scheduling sets a near-1 cap.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Generator, Optional

from repro.core.latency import RuntimeCosts
from repro.core.simulator import Event, Simulator


class CorePool:
    __slots__ = ("sim", "n_cores", "runtime", "busy", "_waiters",
                 "_queued_weight", "busy_time", "served", "_off_pend")

    def __init__(self, sim: Simulator, n_cores: int, runtime: RuntimeCosts):
        self.sim = sim
        self.n_cores = n_cores
        self.runtime = runtime
        self.busy = 0
        # FIFO of waiters; entries are either Event (generator path) or
        # (avail_t, cb, args, weight) tuples (event-heap fast path) —
        # both paths drain through _grant_next so mixed traffic (a fast
        # open loop plus legacy deploy/invoke processes) shares one queue
        self._waiters: deque = deque()
        self._queued_weight = 0     # extra backlog weight of fast waiters
        # lazy releases: absolute times at which a held core frees
        # without a scheduled event (see release_at) — a float min-heap
        self._off_pend: list = []
        # accounting
        self.busy_time = 0.0
        self.served = 0

    # -- inspection ------------------------------------------------------
    @property
    def backlog(self) -> int:
        return len(self._waiters) + self._queued_weight

    def thrash(self) -> float:
        r = self.runtime
        x = self.backlog / max(1, self.n_cores)
        return min(r.thrash_cap, 1.0 + r.thrash_coeff * x)

    def utilization(self, horizon: float) -> float:
        return self.busy_time / (horizon * self.n_cores) if horizon > 0 else 0.0

    # -- usage -------------------------------------------------------
    def consume(self, cpu_time: float) -> Generator:
        """Process-style: yield from pool.consume(t)."""
        ev: Optional[Event] = None
        if self._off_pend:
            self._drain(self.sim.now)
        if self.busy >= self.n_cores:
            if self._off_pend:
                self._materialize()
            ev = self.sim.event()
            self._waiters.append(ev)
            yield ev
        self.busy += 1
        eff = cpu_time * self.thrash()
        yield self.sim.timeout(eff)
        self.busy -= 1
        self.busy_time += eff
        self.served += 1
        self._grant_next()

    # -- event-heap fast path --------------------------------------------
    #
    # The flat driver (repro.core.workload.drive, engine="events") holds
    # cores without generator machinery: ``acquire_fast`` grants a core
    # and calls ``cb(start_t, *args)``; the callee times its own hold and
    # releases with ``release_fast``.  Thrash semantics match ``consume``
    # (multiplier read at grant time).

    def acquire_fast(self, avail_t: float, cb, args=(), weight: int = 1):
        """Request one core for a hold that can start no earlier than
        ``avail_t`` (the caller's in-flight network gap).  When a core is
        free the grant is immediate — reserving through a µs-scale future
        ``avail_t`` while at least one other core stays free, which costs
        capacity only when the pool is nearly full, where the wakeup
        event below takes over instead.  ``weight`` is this waiter's
        contribution to the thrash backlog (a merged off-path job stands
        for several legacy jobs)."""
        if self._off_pend:
            self._drain(self.sim.now)
        busy = self.busy
        nc = self.n_cores
        if busy < nc and not self._waiters:
            now = self.sim.now
            if avail_t <= now:
                self.busy = busy + 1
                cb(now, *args)
            elif busy < nc - 1:
                self.busy = busy + 1
                cb(avail_t, *args)
            else:
                self.sim._schedule(avail_t - now, self.acquire_fast,
                                   avail_t, cb, args, weight)
        else:
            if self._off_pend:
                self._materialize()
            self._waiters.append((avail_t, cb, args, weight))
            self._queued_weight += weight - 1

    def release_fast(self, eff: float) -> None:
        self.busy -= 1
        self.busy_time += eff
        self.served += 1
        if self._waiters:
            self._grant_next()

    # -- lazy releases (kernel-bypass for off-path core holds) ------------
    #
    # A held core whose release time is already known can free *without*
    # a scheduled event: ``release_at`` records the absolute time on a
    # float min-heap, every pool reader drains expired entries first,
    # and the moment anything has to queue (contention) the pending
    # releases materialise into real heap events so waiting grants still
    # fire at the exact release times.  Invariant: ``_off_pend`` is
    # non-empty only while the waiter queue is empty — enforced at run
    # time by ``repro.analysis.sanitizer`` (REPRO_SIM_CHECK=1), which
    # also bounds ``busy`` transitions against ``n_cores``.

    def release_at(self, t: float) -> None:
        """Lazily release one already-held busy core at absolute ``t``
        (the caller incremented ``busy``; busy_time/served accounting
        stays with the caller)."""
        heapq.heappush(self._off_pend, t)

    def _drain(self, now: float) -> None:
        op = self._off_pend
        while op and op[0] <= now:
            heapq.heappop(op)
            self.busy -= 1

    def _materialize(self) -> None:
        sched = self.sim._schedule
        now = self.sim.now
        for t in self._off_pend:
            sched(t - now, self._lazy_release)
        self._off_pend.clear()

    def _lazy_release(self) -> None:
        self.busy -= 1
        if self._waiters:
            self._grant_next()

    def _grant_next(self) -> None:
        waiters = self._waiters
        if waiters and self.busy < self.n_cores:
            w = waiters.popleft()
            if type(w) is tuple:
                avail_t, cb, args, weight = w
                self._queued_weight -= weight - 1
                self.busy += 1
                now = self.sim.now
                cb(avail_t if avail_t > now else now, *args)
            else:
                w.succeed()

    def remove_cores(self, n: int) -> None:
        """Dedicate cores elsewhere (e.g. per-instance polling)."""
        self.n_cores = max(0, self.n_cores - n)

    def add_cores(self, n: int) -> None:
        self.n_cores += n
