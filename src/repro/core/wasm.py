"""Wasm-style lightweight FaaS sandbox (arXiv:2010.07115, WasmEdge-class),
modeled.

Functions are WebAssembly modules instantiated in-process from a compiled
image: cold start is sub-millisecond and OS interactions go through a
thin WASI shim, but the compute itself pays a moderate AOT/JIT overhead
versus native code, and networking still rides the kernel stack.  This
occupies the "instant cold start, moderate datapath" corner of the
backend trade-off space — the opposite bet from quark.
"""
from __future__ import annotations

from repro.core.backends import ColdStartModel, register_backend
from repro.core.containerd import Containerd
from repro.core.latency import (KERNEL_STACK, WASM_COLDSTART_MS,
                                WASM_QUERY_MS, WASM_RUNTIME)


@register_backend
class WasmSandbox(Containerd):
    """Container-shaped lifecycle with sub-ms instantiation and a
    work-multiplier on the function body (interpreted/JIT compute)."""

    name = "wasm"
    runtime = WASM_RUNTIME
    stack_costs = KERNEL_STACK
    coldstart = ColdStartModel(deploy_ms=WASM_COLDSTART_MS,
                               scale_factor=0.5,
                               query_ms=WASM_QUERY_MS)
