"""Controller-side autoscaling (paper §2.1: "outside of the critical path,
the controller performs autoscaling for both the pool and the function
instances").

The controller samples each function's load signal on a control period and
scales the replica count (uProcs inside a Junction instance, or containers)
within policy bounds.  Two pluggable :class:`ScalePolicy` implementations:

* :class:`QueueDepthPolicy` — the classic queue-depth controller: double
  when in-flight exceeds the per-replica target, halve below the
  hysteresis band, on a fixed control period.
* :class:`LeadTimePolicy` — backend-aware: both the control period and the
  scale-up headroom derive from the backend's
  :class:`~repro.core.backends.ColdStartModel`.  A backend that adds a
  replica in 0.2 ms (junctiond uProc spawn) can afford a tight control
  loop and just-in-time capacity; one that takes 270 ms (containerd task
  start) must sample slowly and over-provision for the arrivals that land
  during its scale-up lead time.  This is the asymmetry the paper's
  cold-start section is about, turned into control-plane behaviour.

Replica truth always comes from the backend lifecycle (``lookup``), never
from a shadow dict — an externally removed function simply drops out of
the control loop (no ghost scale events), and a redeploy re-enters it with
the backend's real replica count.

Every decision is recorded as a structured :class:`ScaleEvent` carrying
the request→decision→ready timestamps, so experiments can measure
scale-up *reaction time* (demand exceeding capacity until new capacity is
ready) — the production-scale metric FaaSNet (arXiv:2105.11229) gates on.
"""
from __future__ import annotations

import abc
import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.backends import ColdStartModel, UnknownFunctionError
from repro.core.faas import FaasdRuntime
from repro.core.simulator import Simulator


# ---------------------------------------------------------------------------
# Policies.


@dataclasses.dataclass(frozen=True)
class ScalePolicy(abc.ABC):
    """Pluggable scaling policy: how often to sample, and what replica
    count to want given the load signal.

    A frozen dataclass base: the shared bounds below are the contract
    the :class:`Autoscaler` relies on; implementations add their own
    knobs and set a class-level ``kind``.
    """

    min_replicas: int = 1
    max_replicas: int = 16
    target_inflight_per_replica: float = 4.0
    scale_down_hysteresis: float = 0.5   # scale down below target*this

    kind = ""

    @abc.abstractmethod
    def control_period(self, coldstart: ColdStartModel) -> float:
        """Seconds between controller samples for this backend."""

    @abc.abstractmethod
    def desired(self, *, inflight: float, replicas: int,
                arrival_rate_rps: float,
                coldstart: ColdStartModel) -> int:
        """Replica count to converge to, already clamped to the bounds."""

    # -- shared helpers ---------------------------------------------------
    def clamp(self, want: int) -> int:
        return max(self.min_replicas, min(self.max_replicas, int(want)))

    def overloaded(self, inflight: float, replicas: int) -> bool:
        return inflight > self.target_inflight_per_replica * max(replicas, 0)

    def underloaded(self, inflight: float, replicas: int) -> bool:
        return (inflight < self.target_inflight_per_replica * replicas
                * self.scale_down_hysteresis)


@dataclasses.dataclass(frozen=True)
class QueueDepthPolicy(ScalePolicy):
    """Queue-depth + utilisation driven (the pre-refactor behaviour):
    multiplicative increase/decrease on a fixed control period."""

    period_s: float = 0.25

    kind = "queue-depth"

    def control_period(self, coldstart: ColdStartModel) -> float:
        return self.period_s

    def desired(self, *, inflight, replicas, arrival_rate_rps, coldstart):
        cur = max(1, replicas)
        if self.overloaded(inflight, replicas):
            want = cur * 2
        elif self.underloaded(inflight, replicas) and cur > self.min_replicas:
            want = cur // 2
        else:
            want = replicas
        return self.clamp(want)


@dataclasses.dataclass(frozen=True)
class LeadTimePolicy(ScalePolicy):
    """Backend-aware policy: control period and scale-up headroom derive
    from the backend's :class:`ColdStartModel`.

    * period = clamp(``lead_mult`` x the backend's per-replica scale-up
      time, [``period_floor_s``, ``period_ceil_s``]) — a sub-ms backend
      samples every 10 ms; a 270 ms backend samples at the ceiling.
    * on overload, capacity is sized for *now plus the lead time*: the
      replicas needed for the observed in-flight load, plus headroom for
      the arrivals expected to land while the scale-up is in flight
      (``arrival_rate x scale_seconds`` requests).  Fast backends get
      just-in-time capacity; slow ones must over-provision.
    """

    period_floor_s: float = 0.01
    period_ceil_s: float = 0.25
    lead_mult: float = 2.0

    kind = "lead-time"

    def control_period(self, coldstart: ColdStartModel) -> float:
        return min(self.period_ceil_s,
                   max(self.period_floor_s,
                       self.lead_mult * coldstart.scale_seconds))

    def desired(self, *, inflight, replicas, arrival_rate_rps, coldstart):
        target = self.target_inflight_per_replica
        need = math.ceil(inflight / target) if inflight > 0 else 0
        if self.overloaded(inflight, replicas):
            lead_arrivals = arrival_rate_rps * coldstart.scale_seconds
            headroom = math.ceil(lead_arrivals / target)
            want = need + headroom
        elif self.underloaded(inflight, replicas) \
                and replicas > self.min_replicas:
            want = max(need, replicas // 2)
        else:
            want = replicas
        return self.clamp(want)


# ---------------------------------------------------------------------------
# Telemetry.


@dataclasses.dataclass
class ScaleEvent:
    """One controller decision, with the full request→decision→ready
    timeline.  ``t_request`` is when demand first exceeded capacity (the
    pressure onset; equals ``t_decision`` for scale-downs), ``t_decision``
    the controller tick that acted, ``t_ready`` when the backend finished
    the scale operation (NaN while still in flight / if aborted)."""

    fn: str
    from_replicas: int
    to_replicas: int
    t_request: float
    t_decision: float
    t_ready: float = math.nan
    aborted: bool = False

    @property
    def up(self) -> bool:
        return self.to_replicas > self.from_replicas

    @property
    def cold_starts(self) -> int:
        """Replicas this event had to create."""
        return max(0, self.to_replicas - self.from_replicas)

    @property
    def ready(self) -> bool:
        return math.isfinite(self.t_ready)

    @property
    def reaction_s(self) -> float:
        """Demand-exceeds-capacity until the new capacity is ready."""
        return self.t_ready - self.t_request


# ---------------------------------------------------------------------------
# The controller.


class Autoscaler:
    """Controller loop scaling every deployed function per its policy.

    Load signal: the autoscaler implements the
    :class:`repro.core.workload.SimObserver` protocol — pass it as the
    ``observer`` of :func:`repro.core.workload.drive` and every admitted
    arrival/completion feeds ``on_arrival``/``on_done``.  The
    controller samples the *peak* in-flight count per control period, so
    bursts shorter than the period still register.  Replica truth comes
    from the backend's ``lookup`` — there is no shadow replica dict.
    """

    def __init__(self, sim: Simulator, runtime: FaasdRuntime,
                 policy: Optional[ScalePolicy] = None):
        self.sim = sim
        self.runtime = runtime
        self.policy = policy or QueueDepthPolicy()
        self.inflight: Dict[str, int] = {}
        self.scale_events: List[ScaleEvent] = []
        self.cold_path_arrivals = 0     # arrivals while a scale-up was in flight
        self.cold_starts = 0            # replicas created by completed scale-ups
        self._peak: Dict[str, int] = {}
        self._pressure_t0: Dict[str, float] = {}
        self._arrivals: Dict[str, int] = {}
        self._window_t0: Dict[str, float] = {}
        self._scaling: Dict[str, ScaleEvent] = {}

    # -- load signal ------------------------------------------------------
    def on_arrival(self, fn: str) -> None:
        load = self.inflight.get(fn, 0) + 1
        self.inflight[fn] = load
        self._peak[fn] = max(self._peak.get(fn, 0), load)
        self._arrivals[fn] = self._arrivals.get(fn, 0) + 1
        ev = self._scaling.get(fn)
        if ev is not None and ev.up:
            self.cold_path_arrivals += 1
        cur = self.replicas(fn)
        if cur is None:
            return
        if self.policy.overloaded(load, cur):
            self._pressure_t0.setdefault(fn, self.sim.now)

    def on_done(self, fn: str) -> None:
        self.inflight[fn] = max(0, self.inflight.get(fn, 0) - 1)

    # -- state ------------------------------------------------------------
    def replicas(self, fn: str) -> Optional[int]:
        """Replica truth from the backend lifecycle (None if undeployed)."""
        rec = self.runtime.manager.lookup(fn)
        return None if rec is None else rec.replicas

    # -- the control loop -------------------------------------------------
    def run(self):
        period = self.policy.control_period(self.runtime.backend.coldstart)

        def loop():
            while True:
                yield self.sim.timeout(period)
                self._tick(period)

        return self.sim.process(loop())

    def _drop_state(self, fn: str) -> None:
        self.inflight.pop(fn, None)
        self._peak.pop(fn, None)
        self._pressure_t0.pop(fn, None)
        self._arrivals.pop(fn, None)
        self._window_t0.pop(fn, None)

    def _tick(self, period: float) -> None:
        now = self.sim.now
        for fn in list(self.runtime.functions):
            cur = self.replicas(fn)
            if cur is None:
                # externally removed: no ghost scale events, no stale state
                self._drop_state(fn)
                continue
            if fn in self._scaling:
                # previous op still converging: keep accumulating the
                # peak/rate signal, decide once it lands
                continue
            window = now - self._window_t0.get(fn, now - period)
            self._window_t0[fn] = now
            rate = self._arrivals.pop(fn, 0) / max(window, 1e-9)
            peak = self._peak.pop(fn, 0)
            load = max(self.inflight.get(fn, 0), peak)
            if self.policy.overloaded(load, cur):
                self._pressure_t0.setdefault(fn, now)
            else:
                # pressure subsided without a scale-up (e.g. clamped at
                # max_replicas, or the burst drained): clear the onset so
                # a later scale-up doesn't inherit it and report an
                # inflated reaction time
                self._pressure_t0.pop(fn, None)
            want = self.policy.desired(
                inflight=load, replicas=cur, arrival_rate_rps=rate,
                coldstart=self.runtime.backend.coldstart)
            if want != cur:
                self._issue(fn, cur, want)

    def _issue(self, fn: str, cur: int, want: int) -> None:
        now = self.sim.now
        ev = ScaleEvent(
            fn=fn, from_replicas=cur, to_replicas=want,
            t_request=self._pressure_t0.get(fn, now) if want > cur else now,
            t_decision=now)
        self.scale_events.append(ev)
        self._scaling[fn] = ev

        def do_scale():
            # off the critical path: its own process, warm traffic
            # never waits on it
            try:
                yield from self.runtime.manager.scale(fn, want)
                ev.t_ready = self.sim.now
                self.cold_starts += ev.cold_starts
            except UnknownFunctionError:
                ev.aborted = True           # raced an external remove
            finally:
                self._scaling.pop(fn, None)
                if ev.up:
                    self._pressure_t0.pop(fn, None)   # pressure served

        self.sim.process(do_scale())

    # -- telemetry --------------------------------------------------------
    def telemetry(self) -> Dict[str, object]:
        """Plain-JSON summary of the run's scale events (the artifact's
        ``autoscaler`` block is pooled from these)."""
        done = [e for e in self.scale_events if e.ready and not e.aborted]
        ups = [e for e in done if e.up]
        return {
            "policy": self.policy.kind,
            "n_scale_events": len(self.scale_events),
            "n_up": sum(1 for e in self.scale_events if e.up),
            "n_down": sum(1 for e in self.scale_events if not e.up),
            "n_aborted": sum(1 for e in self.scale_events if e.aborted),
            "cold_starts": self.cold_starts,
            "cold_path_arrivals": self.cold_path_arrivals,
            "reactions_ms": [round(e.reaction_s * 1e3, 4) for e in ups],
            "timeline": [[round(e.t_ready, 6), e.fn, e.to_replicas]
                         for e in sorted(done, key=lambda e: e.t_ready)],
        }
