"""Controller-side autoscaling (paper §2.1: "outside of the critical path,
the controller performs autoscaling for both the pool and the function
instances").

Queue-depth + utilisation driven: the controller samples each function's
in-flight count on a control period and scales the replica count (uProcs
inside a Junction instance, or containers) within [min, max].  Scale-up
latency is the backend's (3.4 ms junction / 450 ms containerd) — the
asymmetry the paper's cold-start section is about.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.faas import FaasdRuntime
from repro.core.simulator import Simulator


@dataclasses.dataclass
class ScalePolicy:
    min_replicas: int = 1
    max_replicas: int = 16
    target_inflight_per_replica: float = 4.0
    period_s: float = 0.25
    scale_down_hysteresis: float = 0.5   # scale down below target*this


class Autoscaler:
    def __init__(self, sim: Simulator, runtime: FaasdRuntime,
                 policy: ScalePolicy = ScalePolicy()):
        self.sim = sim
        self.runtime = runtime
        self.policy = policy
        self.inflight: Dict[str, int] = {}
        self.replicas: Dict[str, int] = {}
        self.scale_events: List[tuple] = []

    def on_arrival(self, fn: str) -> None:
        self.inflight[fn] = self.inflight.get(fn, 0) + 1

    def on_done(self, fn: str) -> None:
        self.inflight[fn] = max(0, self.inflight.get(fn, 0) - 1)

    def _desired(self, fn: str) -> int:
        p = self.policy
        cur = self.replicas.get(fn, 1)
        load = self.inflight.get(fn, 0)
        if load > p.target_inflight_per_replica * cur:
            want = min(p.max_replicas, cur * 2)
        elif (load < p.target_inflight_per_replica * cur
              * p.scale_down_hysteresis and cur > p.min_replicas):
            want = max(p.min_replicas, cur // 2)
        else:
            want = cur
        return want

    def run(self):
        def loop():
            while True:
                yield self.sim.timeout(self.policy.period_s)
                for fn in list(self.runtime.functions):
                    cur = self.replicas.setdefault(fn, 1)
                    want = self._desired(fn)
                    if want != cur:
                        # off the critical path: kicked as its own process
                        self.sim.process(self.runtime.manager.scale(fn, want))
                        self.replicas[fn] = want
                        self.scale_events.append((self.sim.now, fn, cur, want))
        return self.sim.process(loop())
