"""Deterministic discrete-event engine (SimPy-lite, generator coroutines).

The FaaS runtime (gateway, provider, function instances), both network
stacks, and the Junction scheduler are modelled as processes on this
engine.  Time unit: **seconds** (float); typical granule is microseconds.

Why a DES and not wall-clock threads: the paper's claims are about µs-scale
networking/scheduling behaviour that a CPython process cannot reproduce
natively; a DES makes the *architecture* (hop counts, queue ownership,
polling placement, preemption) explicit and measurable, with calibrated
per-operation costs, and is exactly reproducible for tests.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Any, Callable, Generator, List, Optional

import numpy as np


class Event:
    """One-shot event; processes wait on it, success carries a value."""

    __slots__ = ("sim", "triggered", "value", "_waiters", "callbacks")

    def __init__(self, sim: "Simulator"):
        self.sim = sim
        self.triggered = False
        self.value: Any = None
        self._waiters: List["Process"] = []
        self.callbacks: List[Callable[[Any], None]] = []

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self.value = value
        for cb in self.callbacks:
            cb(value)
        waiters, self._waiters = self._waiters, []
        for proc in waiters:
            self.sim._schedule(0.0, proc._resume, value)
        return self

    def _add_waiter(self, proc: "Process") -> None:
        if self.triggered:
            self.sim._schedule(0.0, proc._resume, self.value)
        else:
            self._waiters.append(proc)


class Timeout(Event):
    def __init__(self, sim: "Simulator", delay: float):
        super().__init__(sim)
        sim._schedule(delay, self._fire)

    def _fire(self) -> None:
        if not self.triggered:
            self.succeed()


class Process:
    """A generator coroutine; yields Events (or Timeouts) to wait."""

    __slots__ = ("sim", "gen", "done", "result", "_completion")

    def __init__(self, sim: "Simulator", gen: Generator):
        self.sim = sim
        self.gen = gen
        self.done = False
        self.result: Any = None
        self._completion: Optional[Event] = None
        sim._schedule(0.0, self._resume, None)

    @property
    def completion(self) -> Event:
        if self._completion is None:
            self._completion = Event(self.sim)
            if self.done:
                self._completion.succeed(self.result)
        return self._completion

    def _resume(self, value: Any = None) -> None:
        if self.done:
            return
        try:
            ev = self.gen.send(value)
        except StopIteration as stop:
            self.done = True
            self.result = getattr(stop, "value", None)
            if self._completion is not None and not self._completion.triggered:
                self._completion.succeed(self.result)
            return
        if not isinstance(ev, Event):
            raise TypeError(f"process yielded {type(ev)}; yield an Event/Timeout")
        ev._add_waiter(self)


class Queue:
    """Unbounded FIFO with blocking get (used for NIC queues, run queues)."""

    __slots__ = ("sim", "items", "_getters")

    def __init__(self, sim: "Simulator"):
        self.sim = sim
        self.items: deque = deque()
        self._getters: deque = deque()

    def put(self, item: Any) -> None:
        if self._getters:
            ev = self._getters.popleft()
            ev.succeed(item)
        else:
            self.items.append(item)

    def get(self) -> Event:
        ev = Event(self.sim)
        if self.items:
            ev.succeed(self.items.popleft())
        else:
            self._getters.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.items)


class EventLoop:
    """Flat-callback fast path over the simulator's event heap.

    The generator :class:`Process` machinery costs several allocations
    and dispatches per wait; at fleet scale (10^7+ simulated requests
    per suite) that overhead dominates wall time.  ``EventLoop`` runs
    the same heap with plain ``(time, counter, fn, args)`` callback
    entries — no Event/Timeout/Process objects on the hot path — and
    merges a presorted arrival stream into the event order without
    materialising one heap entry per arrival.

    Generator processes scheduled on the same simulator (autoscaler
    control loops, backend lifecycle/scale operations, the Junction
    scheduler's poll loop, mid-run provisioning storms) interleave
    exactly as under :meth:`Simulator.run`: both paths share the one
    heap and the one clock, so a fast-driven open loop and a legacy
    generator process can contend for the same :class:`CorePool`.
    """

    __slots__ = ("sim",)

    def __init__(self, sim: "Simulator"):
        self.sim = sim

    def schedule(self, delay: float, fn: Callable, *args) -> None:
        """Schedule a plain callback ``fn(*args)`` after ``delay``."""
        self.sim._schedule(delay, fn, *args)

    def run(self, until: float, arrival_times=None, admit=None) -> int:
        """Drain the heap up to ``until``, delivering ``admit(i, t)``
        for each entry of the presorted ``arrival_times`` sequence,
        merged into the heap's time order (ties: arrival first).

        Mirrors :meth:`Simulator.run` clock/stop semantics: the clock
        lands on ``until`` unless :meth:`Simulator.stop` fired, and
        events beyond ``until`` stay queued.  Returns the number of
        arrivals delivered.

        Invariant: the clock never moves backwards — every popped event
        and every admitted arrival is timestamped at or after ``now``.
        ``repro.analysis.sanitizer`` swaps in an operation-for-operation
        copy of this loop that asserts it (keep the two in sync when
        editing)."""
        sim = self.sim
        heap = sim._heap
        pop = heapq.heappop
        arr = arrival_times if arrival_times is not None else ()
        n_arr = len(arr)
        inf = float("inf")
        i = 0
        # t_ar is loop-invariant between admits, so it is cached and
        # refreshed only when i advances; t_ev must be re-read from the
        # heap every iteration (callbacks and admit push new events)
        t_ar = arr[0] if n_arr else inf
        sim.stopped = False
        while not sim.stopped:
            t_ev = heap[0][0] if heap else inf
            if t_ar <= t_ev:
                if t_ar > until:
                    break
                sim.now = t_ar
                admit(i, t_ar)
                i += 1
                t_ar = arr[i] if i < n_arr else inf
            else:
                if t_ev > until:
                    break
                t, _, fn, args = pop(heap)
                sim.now = t
                fn(*args)
        if not sim.stopped:
            sim.now = max(sim.now, until)
        return i


class Simulator:
    def __init__(self, seed: int = 0):
        self._heap: list = []
        self._counter = itertools.count()
        self.now = 0.0
        self.rng = np.random.default_rng(seed)
        self.stopped = False

    # -- scheduling -----------------------------------------------------
    def _schedule(self, delay: float, fn: Callable, *args) -> None:
        heapq.heappush(self._heap, (self.now + delay, next(self._counter), fn, args))

    def timeout(self, delay: float) -> Timeout:
        return Timeout(self, max(0.0, delay))

    def event(self) -> Event:
        return Event(self)

    def queue(self) -> Queue:
        return Queue(self)

    def process(self, gen: Generator) -> Process:
        return Process(self, gen)

    # -- execution ------------------------------------------------------
    def run(self, until: float = float("inf")) -> None:
        self.stopped = False
        while self._heap and not self.stopped:
            t, _, fn, args = self._heap[0]
            if t > until:
                break
            heapq.heappop(self._heap)
            self.now = t
            fn(*args)
        if until != float("inf") and not self.stopped:
            self.now = max(self.now, until)

    def stop(self) -> None:
        self.stopped = True

    # -- randomness helpers ---------------------------------------------
    def lognormal_us(self, median_us: float, sigma: float) -> float:
        """Lognormal latency in seconds given median in µs."""
        return float(self.rng.lognormal(np.log(median_us), sigma)) * 1e-6

    def exponential(self, mean: float) -> float:
        return float(self.rng.exponential(mean))
