"""Network datapath models: kernel stack vs Junction kernel-bypass.

``deliver`` models one message traversal end-to-end: sender-side
processing, wire, receiver-side processing, and the wakeup of the target
(interrupt + context switch for the kernel path; centralized-scheduler
poll pickup for Junction).  CPU costs are charged to the host core pool;
latency-only components just advance time.
"""
from __future__ import annotations

from typing import Generator

from repro.core.latency import StackCosts
from repro.core.resources import CorePool
from repro.core.simulator import Simulator


class NetStack:
    def __init__(self, sim: Simulator, costs: StackCosts, cores: CorePool):
        self.sim = sim
        self.costs = costs
        self.cores = cores
        # accounting
        self.messages = 0
        self.cpu_spent = 0.0
        self.hiccups = 0

    def _jitter(self, base_us: float) -> float:
        return self.sim.lognormal_us(base_us, self.costs.jitter_sigma)

    def _maybe_hiccup(self) -> float:
        c = self.costs
        if self.sim.rng.random() < c.hiccup_p:
            self.hiccups += 1
            return float(self.sim.rng.uniform(c.hiccup_lo_ms, c.hiccup_hi_ms)) * 1e-3
        return 0.0

    def deliver(self, size_bytes: int = 1024) -> Generator:
        """Process: one one-way message; returns (yields through) when the
        payload is in the receiver's hands (post-wakeup)."""
        c = self.costs
        kb = size_bytes / 1024.0
        # sender side: syscall + stack tx (consumes CPU and adds latency)
        tx_cpu = (c.tx_cpu_us + c.per_kb_us * kb) * 1e-6
        yield from self.cores.consume(tx_cpu)
        self.cpu_spent += tx_cpu
        yield self.sim.timeout(self._jitter(c.send_lat_us))
        # wire
        yield self.sim.timeout(c.wire_us * 1e-6)
        # receiver side: rx processing + wakeup of target thread/uthread
        rx_cpu = (c.rx_cpu_us + c.wakeup_cpu_us + c.per_kb_us * kb) * 1e-6
        yield from self.cores.consume(rx_cpu)
        self.cpu_spent += rx_cpu
        lat = self._jitter(c.rx_lat_us + c.wakeup_us) + self._maybe_hiccup()
        yield self.sim.timeout(lat)
        self.messages += 1
