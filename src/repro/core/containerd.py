"""containerd-backed baseline (mainline faasd): Linux containers as the
function sandbox, kernel network stack, CFS scheduling."""
from __future__ import annotations

import dataclasses
from typing import Dict, Generator, Optional

from repro.core.latency import CONTAINERD_COLDSTART_MS, CONTAINERD_QUERY_MS
from repro.core.simulator import Simulator


@dataclasses.dataclass
class ContainerRecord:
    name: str
    ip: str
    port: int
    replicas: int = 1
    ready: bool = True


class Containerd:
    name = "containerd"
    query_seconds = CONTAINERD_QUERY_MS * 1e-3

    def __init__(self, sim: Simulator):
        self.sim = sim
        self.records: Dict[str, ContainerRecord] = {}
        self.deploys = 0

    def deploy(self, fn_name: str, *, scale: int = 1, max_cores: int = 2,
               isolate_replicas: bool = False) -> Generator:
        """Container create + task start (warm image)."""
        yield self.sim.timeout(CONTAINERD_COLDSTART_MS * 1e-3)
        self.records[fn_name] = ContainerRecord(
            name=fn_name, ip=f"10.62.0.{len(self.records) + 2}", port=8080,
            replicas=scale)
        self.deploys += 1

    def scale(self, fn_name: str, replicas: int) -> Generator:
        # additional container tasks
        yield self.sim.timeout(CONTAINERD_COLDSTART_MS * 1e-3 * 0.6)
        self.records[fn_name].replicas = replicas

    def remove(self, fn_name: str) -> None:
        self.records.pop(fn_name, None)

    def query(self, fn_name: str) -> Generator:
        """GetTask/Status RPC to containerd — ms-scale, can exceed the
        function execution itself (paper §4)."""
        yield self.sim.timeout(self.query_seconds)
        return self.records.get(fn_name)

    def lookup(self, fn_name: str) -> Optional[ContainerRecord]:
        return self.records.get(fn_name)
