"""containerd-backed baseline (mainline faasd): Linux containers as the
function sandbox, kernel network stack, CFS scheduling."""
from __future__ import annotations

import dataclasses
from typing import Generator, Optional

from repro.core.backends import (ColdStartModel, ExecutionBackend,
                                 register_backend)
from repro.core.latency import (CONTAINERD_COLDSTART_MS, CONTAINERD_QUERY_MS,
                                KERNEL_RUNTIME, KERNEL_STACK)


@dataclasses.dataclass
class ContainerRecord:
    name: str
    ip: str
    port: int
    replicas: int = 1
    ready: bool = True


@register_backend
class Containerd(ExecutionBackend):
    """Container-class lifecycle: ms-scale control plane, cold starts in
    the hundreds of ms.  Also the base class for the other modeled
    container-shaped backends (quark/wasm differ only in cost tables)."""

    name = "containerd"
    runtime = KERNEL_RUNTIME
    stack_costs = KERNEL_STACK
    coldstart = ColdStartModel(deploy_ms=CONTAINERD_COLDSTART_MS,
                               scale_factor=0.6,
                               query_ms=CONTAINERD_QUERY_MS)

    def deploy(self, fn_name: str, *, scale: int = 1, max_cores: int = 2,
               isolate_replicas: bool = False) -> Generator:
        """Sandbox create + task start (warm image)."""
        self.remove(fn_name)      # redeploy releases the old sandbox
        yield self.sim.timeout(self.coldstart.deploy_seconds)
        self.records[fn_name] = ContainerRecord(
            name=fn_name, ip=f"10.62.0.{len(self.records) + 2}", port=8080,
            replicas=scale)
        self.deploys += 1

    def scale(self, fn_name: str, replicas: int) -> Generator:
        rec = self._require(fn_name)
        # additional (or reaped) sandbox tasks
        yield self.sim.timeout(self.coldstart.scale_seconds)
        rec.replicas = replicas

    # query(): the inherited GetTask/Status RPC costs CONTAINERD_QUERY_MS —
    # ms-scale, can exceed the function execution itself (paper §4).

    def lookup(self, fn_name: str) -> Optional[ContainerRecord]:
        return self.records.get(fn_name)
