"""Pluggable execution backends for the faasd runtime.

The paper's core move is swapping faasd's execution backend (containerd →
junctiond); this module makes the backend a first-class, registered
abstraction instead of an if/else in :class:`~repro.core.faas.FaasdRuntime`.

An :class:`ExecutionBackend` bundles everything the runtime needs from a
backend:

* **cost tables** — a :class:`~repro.core.latency.RuntimeCosts` (per-hop
  application processing, execution overheads, thrash model) and a
  :class:`~repro.core.latency.StackCosts` (the network datapath);
* **host resources** — the :class:`~repro.core.resources.CorePool`, an
  optional core scheduler (junctiond's centralized poller), and the
  :class:`~repro.core.netstack.NetStack` built from the cost tables;
* **a cold-start model** — :class:`ColdStartModel` with the deploy /
  scale / control-plane-query timing class;
* **the control-plane lifecycle** — ``deploy`` / ``scale`` / ``query`` /
  ``remove`` / ``lookup``, with uniform error behaviour
  (:class:`UnknownFunctionError` on lifecycle ops addressing undeployed
  functions, ``None`` from reads).

Implementations register under a unique name with ``@register_backend``;
:func:`resolve_backend` turns a name (via the registry) or a ready
instance into the bundle ``FaasdRuntime`` composes with.  Adding a
backend therefore never touches ``faas.py`` — see the six built-ins:
``containerd``, ``junctiond`` (the paper's pair), ``quark`` (secure
container runtime, arXiv:2309.12624), ``wasm`` (lightweight sandbox,
arXiv:2010.07115), ``firecracker`` (microVM with snapshot-restore cold
starts) and ``gvisor`` (Sentry-intercepted sandbox, KVM or ptrace
platform).
"""
from __future__ import annotations

import abc
import dataclasses
import importlib
from typing import Dict, Generator, Optional, Tuple, Type, Union

from repro.core.latency import RuntimeCosts, StackCosts
from repro.core.netstack import NetStack
from repro.core.resources import CorePool
from repro.core.scheduler import PollingModel
from repro.core.simulator import Simulator


class UnknownFunctionError(KeyError):
    """A lifecycle operation addressed a function the backend has not
    deployed.  Raised uniformly by every backend (the conformance tests
    pin this), so callers never need backend-specific error handling."""

    def __init__(self, backend: str, fn_name: str):
        super().__init__(fn_name)
        self.backend = backend
        self.fn_name = fn_name

    def __str__(self) -> str:
        return (f"backend {self.backend!r} has no deployed function "
                f"{self.fn_name!r}")


@dataclasses.dataclass(frozen=True)
class ColdStartModel:
    """Control-plane timing class of a backend.

    ``deploy_ms`` is sandbox/instance creation until first-invoke ready
    (container create+start, Junction instance init, Wasm instantiate);
    ``scale_factor`` is the marginal cost of one additional replica as a
    fraction of a full deploy; ``query_ms`` is the control-plane state
    query the provider cache keeps off the warm path (paper §4).
    """
    deploy_ms: float
    scale_factor: float
    query_ms: float

    @property
    def deploy_seconds(self) -> float:
        return self.deploy_ms * 1e-3

    @property
    def scale_seconds(self) -> float:
        return self.deploy_ms * self.scale_factor * 1e-3

    @property
    def query_seconds(self) -> float:
        return self.query_ms * 1e-3


@dataclasses.dataclass(frozen=True)
class SnapshotColdStartModel(ColdStartModel):
    """Two-mode cold-start class for snapshotting backends (Firecracker
    microVMs): a function's *first* cold start pays the full boot
    (``deploy_ms``) and warms a per-function snapshot; every later cold
    start restores from that snapshot in ``restore_ms``.

    ``scale_seconds`` — what
    :class:`~repro.core.autoscaler.LeadTimePolicy` derives its control
    period and headroom from — and ``scale_factor`` are both **derived
    from the restore path** (scale-ups always run against a snapshot the
    deploy already warmed); callers pass ``restore_ms`` and cannot
    desynchronise the marginal replica cost from it.

    ``save_ms`` is the snapshot-warming surcharge the *boot* path pays
    (pause the VM, serialize memory + device state) before the cache
    can serve restores: a full boot costs ``boot_seconds`` =
    ``deploy_seconds + save_seconds``, and boot-without-save
    (``deploy_seconds``) is the floor the conformance suite pins.
    """
    restore_ms: float = 0.0
    scale_factor: float = dataclasses.field(default=0.0, kw_only=True)
    save_ms: float = 0.0

    def __post_init__(self):
        if not 0 < self.restore_ms < self.deploy_ms:
            raise ValueError(
                f"restore_ms must be in (0, deploy_ms={self.deploy_ms}), "
                f"got {self.restore_ms} — a snapshot restore is the cheap "
                "mode of a two-mode cold start")
        if self.save_ms < 0:
            raise ValueError(
                f"save_ms must be >= 0, got {self.save_ms}")
        object.__setattr__(self, "scale_factor",
                           self.restore_ms / self.deploy_ms)

    @property
    def restore_seconds(self) -> float:
        return self.restore_ms * 1e-3

    @property
    def save_seconds(self) -> float:
        return self.save_ms * 1e-3

    @property
    def boot_seconds(self) -> float:
        """Full first boot: sandbox bring-up plus snapshot warming."""
        return (self.deploy_ms + self.save_ms) * 1e-3

    @property
    def scale_seconds(self) -> float:
        # one extra replica = one snapshot restore, never a full boot
        return self.restore_seconds


class ExecutionBackend(abc.ABC):
    """One execution backend: cost tables + host resources + lifecycle.

    Subclasses set the four class attributes and implement the lifecycle;
    ``_build_scheduler``/``_start_services`` are wiring hooks for backends
    that reserve cores or run runtime services inside their own sandboxes
    (junctiond does both).
    """

    # -- identity + cost tables (class attributes on implementations) -----
    name: str = ""                      # unique registry key
    runtime: RuntimeCosts
    stack_costs: StackCosts
    coldstart: ColdStartModel

    def __init__(self, sim: Simulator, *, n_cores: int = 10,
                 polling_model: PollingModel = PollingModel.CENTRALIZED):
        self.sim = sim
        self.cores = CorePool(sim, n_cores, self.runtime)
        self.scheduler = self._build_scheduler(polling_model)
        self.stack = NetStack(sim, self.stack_costs, self.cores)
        self.records: Dict[str, object] = {}
        self.deploys = 0
        self._start_services()

    # -- wiring hooks -----------------------------------------------------
    def _build_scheduler(self, polling_model: PollingModel):
        """Core scheduler for this backend; None means host CFS."""
        return None

    def _start_services(self) -> None:
        """Bring up the faasd runtime services (gateway/provider) if the
        backend hosts them in its own sandboxes."""

    # -- control-plane lifecycle -----------------------------------------
    @abc.abstractmethod
    def deploy(self, fn_name: str, *, scale: int = 1, max_cores: int = 2,
               isolate_replicas: bool = False) -> Generator:
        """Process: create the function's sandbox(es); yields until ready.
        Re-deploying an existing name first releases the old *runtime*
        resources (sandboxes, scheduler registrations — as :meth:`remove`
        would) so config updates never leak.  One deliberate exception:
        a snapshotting backend keeps the function's image-keyed snapshot
        across redeploys so they restore fast; only :meth:`remove` (full
        teardown) evicts it.  See the snapshot-cache lifecycle contract
        in ROADMAP.md and the conformance tests."""

    @abc.abstractmethod
    def scale(self, fn_name: str, replicas: int) -> Generator:
        """Process: adjust the replica count of a **deployed** function.
        Must raise :class:`UnknownFunctionError` for undeployed names."""

    def remove(self, fn_name: str) -> None:
        """Tear down the function and release every resource it held.
        Removing an unknown function is a no-op (idempotent teardown)."""
        self.records.pop(fn_name, None)

    def query(self, fn_name: str) -> Generator:
        """Process: control-plane state query (GetTask/Status RPC class);
        returns the record, or None for unknown names."""
        yield self.sim.timeout(self.coldstart.query_seconds)
        return self.records.get(fn_name)

    def lookup(self, fn_name: str):
        """Zero-cost read of the backend's record (provider-cache fill)."""
        return self.records.get(fn_name)

    # -- shared helpers ---------------------------------------------------
    @property
    def query_seconds(self) -> float:
        return self.coldstart.query_seconds

    def _require(self, fn_name: str):
        try:
            return self.records[fn_name]
        except KeyError:
            raise UnknownFunctionError(self.name, fn_name) from None


# ---------------------------------------------------------------------------
# Registry.

_REGISTRY: Dict[str, Type[ExecutionBackend]] = {}

# Modules that register the built-in backends on import.  Imported lazily
# (the implementations import this module for the base class).
_BUILTIN_MODULES = (
    "repro.core.containerd",
    "repro.core.junctiond",
    "repro.core.quark",
    "repro.core.wasm",
    "repro.core.firecracker",
    "repro.core.gvisor",
)


def register_backend(cls: Type[ExecutionBackend]) -> Type[ExecutionBackend]:
    """Class decorator: register ``cls`` under ``cls.name``."""
    name = getattr(cls, "name", "")
    if not name:
        raise ValueError(f"{cls.__qualname__} must set a non-empty `name`")
    existing = _REGISTRY.get(name)
    if existing is not None and existing is not cls:
        raise ValueError(f"backend name {name!r} already registered by "
                         f"{existing.__qualname__}")
    _REGISTRY[name] = cls
    return cls


def _ensure_builtins() -> None:
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)


def available_backends() -> Tuple[str, ...]:
    """Sorted names of every registered backend."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def get_backend_class(name: str) -> Type[ExecutionBackend]:
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered backends: "
                         f"{', '.join(sorted(_REGISTRY))}") from None


def resolve_backend(backend: Union[str, ExecutionBackend], sim: Simulator, *,
                    n_cores: Optional[int] = None,
                    polling_model: Optional[PollingModel] = None,
                    ) -> ExecutionBackend:
    """Name (via the registry) or ready instance -> attached backend.

    A ready instance must already be bound to ``sim`` and fully
    configured; passing ``n_cores``/``polling_model`` alongside one is
    rejected rather than silently ignored.
    """
    if isinstance(backend, ExecutionBackend):
        if backend.sim is not sim:
            raise ValueError(
                f"backend instance {backend.name!r} is bound to a different "
                "Simulator; build it on the runtime's simulator")
        if n_cores is not None or polling_model is not None:
            raise ValueError(
                "n_cores/polling_model cannot be applied to a ready backend "
                "instance; configure the instance at construction instead")
        return backend
    # only pass what the caller actually set, so a backend class remains
    # the single source of its own constructor defaults
    kwargs = {}
    if n_cores is not None:
        kwargs["n_cores"] = n_cores
    if polling_model is not None:
        kwargs["polling_model"] = polling_model
    return get_backend_class(backend)(sim, **kwargs)
