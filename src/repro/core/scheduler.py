"""Centralized Junction scheduler (paper §2.2.1 "Scheduler").

One reserved core busy-polls (a) the NIC event queues of every instance
and (b) uthread runnable state, and (re)allocates cores.  The key
scalability property the paper claims — and this model preserves and the
tests assert — is that per-decision work is proportional to the number of
**cores managed**, not the number of **instances hosted**:

  * event queues are armed: the scheduler maintains a compact list of
    signalled instances (hardware writes the event queue; the scheduler
    drains only non-empty queues), so an idle instance costs nothing per
    poll iteration;
  * core grant/preempt decisions touch only the active-core set.

``PollingModel.PER_INSTANCE`` models the naive DPDK-style alternative
(one dedicated polling core per isolated application) used as the
resource-efficiency baseline.
"""
from __future__ import annotations

import enum
from typing import Dict, List

from repro.core.junction import JunctionInstance
from repro.core.resources import CorePool
from repro.core.simulator import Simulator

POLL_QUANTUM_US = 50.0         # scheduler *allocation* loop period (packet
                               # pickup latency is modelled in the netstack)
PREEMPT_QUANTUM_US = 100.0     # max uninterrupted core grant
POLL_BATCH = 8                 # quanta simulated per heap event: the loop
                               # accounts every 50µs iteration but only
                               # materialises one event per batch — grants
                               # gate nothing on the request path, so the
                               # coarser event spacing is unobservable


class PollingModel(str, enum.Enum):
    CENTRALIZED = "centralized"      # Junction: 1 reserved core for all
    PER_INSTANCE = "per_instance"    # naive kernel-bypass: 1 core each


class JunctionScheduler:
    def __init__(self, sim: Simulator, cores: CorePool,
                 model: PollingModel = PollingModel.CENTRALIZED):
        self.sim = sim
        self.cores = cores
        self.model = model
        self.instances: List[JunctionInstance] = []
        self.grants: Dict[int, int] = {}
        # accounting (exposed to tests/benchmarks)
        self.poll_iterations = 0
        self.decision_work = 0        # units ∝ cores examined
        self.polling_cores_reserved = 0
        self.preemptions = 0
        if model == PollingModel.CENTRALIZED:
            cores.remove_cores(1)     # the reserved scheduler core
            self.polling_cores_reserved = 1

    # -- registration ---------------------------------------------------
    def register(self, inst: JunctionInstance) -> None:
        self.instances.append(inst)
        self.grants[inst.id] = 0
        if self.model == PollingModel.PER_INSTANCE:
            # dedicated polling core per isolated instance (DPDK-style)
            self.cores.remove_cores(1)
            self.polling_cores_reserved += 1

    def unregister(self, inst: JunctionInstance) -> None:
        self.instances.remove(inst)
        self.grants.pop(inst.id, None)
        if self.model == PollingModel.PER_INSTANCE:
            self.cores.add_cores(1)
            self.polling_cores_reserved -= 1

    # -- the polling loop (runs forever on the reserved core) ------------
    #
    # Flat self-rescheduling callback rather than a generator process:
    # at 50µs period the loop fires 20k times per simulated second, and
    # the Process/Timeout machinery per iteration would dominate the
    # event-heap driver's wall time.  Semantics are unchanged — one
    # allocation pass per quantum on the shared heap.
    def run(self) -> None:
        self.sim._schedule(0.0, self._tick)

    def _tick(self) -> None:
        self.poll_iterations += POLL_BATCH
        # Drain signalled event queues only (compact active list).
        n_cores = self.cores.n_cores
        demand = 0
        active = []
        for inst in self.instances:
            d = inst.core_demand
            if d > 0 or inst.event_queue.items:
                inst.event_queue.items.clear()
                demand += d
                active.append((inst, d))
        # Allocation decision: work ∝ cores managed (active set),
        # NOT ∝ len(self.instances).
        self.decision_work += POLL_BATCH * max(1, min(n_cores, demand))
        granted = 0
        grants = self.grants
        for inst, d in active:
            g = min(d, n_cores - granted)
            prev = grants[inst.id]
            if prev > g:
                self.preemptions += prev - g
            grants[inst.id] = g
            granted += g
            if granted >= n_cores:
                break
        self.sim._schedule(POLL_BATCH * POLL_QUANTUM_US * 1e-6, self._tick)

    # -- properties the paper argues about -------------------------------
    def polling_cost_per_iteration(self) -> float:
        """Average decision work per poll — should track cores, not
        instance count (asserted in tests)."""
        return self.decision_work / max(1, self.poll_iterations)
