# The paper's primary contribution: junctiond — kernel-bypass execution
# backend for faasd — modelled as a composable system: a deterministic
# discrete-event runtime hosting the faasd components (gateway, provider),
# a registry of pluggable execution backends (containerd, junctiond, and
# the modeled quark/wasm/firecracker/gvisor backends from related work),
# the network datapaths, and the centralized polling scheduler.
from repro.core.autoscaler import (Autoscaler, LeadTimePolicy,
                                   QueueDepthPolicy, ScaleEvent, ScalePolicy)
from repro.core.backends import (ColdStartModel, ExecutionBackend,
                                 SnapshotColdStartModel,
                                 UnknownFunctionError, available_backends,
                                 get_backend_class, register_backend,
                                 resolve_backend)
from repro.core.containerd import Containerd
from repro.core.faas import (FaasdRuntime, FunctionSpec, InvocationPlan,
                             InvocationRecord)
from repro.core.firecracker import Firecracker, SnapshotCache
from repro.core.gvisor import GVisor
from repro.core.junction import JunctionInstance, UProc
from repro.core.junctiond import Junctiond
from repro.core.netstack import NetStack
from repro.core.quark import Quark
from repro.core.resources import CorePool
from repro.core.scheduler import JunctionScheduler, PollingModel
from repro.core.simulator import Event, EventLoop, Process, Queue, Simulator
from repro.core.wasm import WasmSandbox
from repro.core.workload import (ArrivalProcess, BurstyArrivals, ChainEdge,
                                 DiurnalArrivals, FusionPlan, KneeSearch,
                                 KneeSearchResult, LatencySummary, LoadSpec,
                                 NullObserver, PoissonArrivals, SimObserver,
                                 TraceReplay, drive, heavy_tailed_work,
                                 knee_index_of_curve, knee_of_curve,
                                 run_mixed_open_loop, run_open_loop,
                                 run_sequential, sustainable_throughput)

__all__ = [
    "Autoscaler", "ScalePolicy", "QueueDepthPolicy", "LeadTimePolicy",
    "ScaleEvent",
    "ColdStartModel", "SnapshotColdStartModel", "ExecutionBackend",
    "UnknownFunctionError",
    "available_backends", "get_backend_class", "register_backend",
    "resolve_backend",
    "Containerd", "FaasdRuntime", "FunctionSpec", "InvocationPlan",
    "InvocationRecord",
    "Firecracker", "SnapshotCache", "GVisor",
    "JunctionInstance", "UProc", "Junctiond", "Quark", "WasmSandbox",
    "NetStack", "CorePool",
    "JunctionScheduler", "PollingModel", "Event", "EventLoop", "Process",
    "Queue",
    "Simulator", "LatencySummary", "LoadSpec", "ChainEdge", "FusionPlan",
    "SimObserver", "NullObserver",
    "drive", "run_open_loop", "run_sequential",
    "sustainable_throughput",
    "ArrivalProcess", "PoissonArrivals", "BurstyArrivals", "DiurnalArrivals",
    "TraceReplay", "heavy_tailed_work", "knee_of_curve",
    "knee_index_of_curve", "KneeSearch", "KneeSearchResult",
    "run_mixed_open_loop",
]

# Opt-in runtime invariant checks (see repro.analysis.sanitizer): with
# REPRO_SIM_CHECK=1 in the environment, every process importing the sim
# core runs with the checked EventLoop/CorePool wrappers installed.
import os as _os

if _os.environ.get("REPRO_SIM_CHECK", "") not in ("", "0"):
    from repro.analysis import sanitizer as _sanitizer
    _sanitizer.install()
