"""junctiond — the paper's contribution (§3/§4): a function manager that
replaces containerd, deploying faasd components and user functions inside
Junction instances.

Responsibilities (paper §4): configure instance networking, deploy
instances via ``junction_run``, monitor running state.  junctiond itself
is the only component outside a Junction instance (it must spawn new host
processes).  Scale-up of a function either (a) adds uProcs to an existing
instance (runtimes without native parallelism, e.g. Python), (b) raises
the instance's core cap, or (c) spawns an isolated sibling instance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Generator, List, Optional

from repro.core.junction import JunctionInstance
from repro.core.latency import JUNCTIOND_QUERY_MS
from repro.core.scheduler import JunctionScheduler
from repro.core.simulator import Simulator


@dataclasses.dataclass
class FunctionRecord:
    name: str
    instances: List[JunctionInstance]
    ip: str
    port: int
    replicas: int = 1

    @property
    def ready(self) -> bool:
        return all(i.ready for i in self.instances)


class Junctiond:
    name = "junctiond"
    query_seconds = JUNCTIOND_QUERY_MS * 1e-3

    def __init__(self, sim: Simulator, scheduler: JunctionScheduler):
        self.sim = sim
        self.scheduler = scheduler
        self.records: Dict[str, FunctionRecord] = {}
        self.deploys = 0

    # -- lifecycle -------------------------------------------------------
    def deploy(self, fn_name: str, *, scale: int = 1, max_cores: int = 2,
               isolate_replicas: bool = False) -> Generator:
        """Process: spawn Junction instance(s) via `junction_run` and
        configure networking.  Yields until ready."""
        insts: List[JunctionInstance] = []
        n_instances = scale if isolate_replicas else 1
        for i in range(n_instances):
            inst = JunctionInstance(self.sim, f"{fn_name}#{i}",
                                    max_cores=max_cores)
            # paper §5: 3.4 ms measured instance init (single-threaded)
            yield self.sim.timeout(JunctionInstance.INIT_SECONDS)
            if not isolate_replicas:
                for j in range(scale):
                    inst.spawn_uproc(f"{fn_name}/uproc{j}")
            else:
                inst.spawn_uproc(f"{fn_name}/uproc0")
            inst.ready = True
            self.scheduler.register(inst)
            insts.append(inst)
        self.records[fn_name] = FunctionRecord(
            name=fn_name, instances=insts, ip=f"10.62.0.{len(self.records) + 2}",
            port=8080, replicas=scale)
        self.deploys += 1

    def scale(self, fn_name: str, replicas: int) -> Generator:
        rec = self.records[fn_name]
        inst = rec.instances[0]
        while len(inst.uprocs) < replicas:
            inst.spawn_uproc(f"{fn_name}/uproc{len(inst.uprocs)}")
            yield self.sim.timeout(0.2e-3)  # uProc spawn inside the libOS
        rec.replicas = replicas

    def remove(self, fn_name: str) -> None:
        rec = self.records.pop(fn_name, None)
        if rec:
            for inst in rec.instances:
                self.scheduler.unregister(inst)

    # -- control-plane state query (what the provider cache avoids) -------
    def query(self, fn_name: str) -> Generator:
        yield self.sim.timeout(self.query_seconds)
        return self.records.get(fn_name)

    def lookup(self, fn_name: str) -> Optional[FunctionRecord]:
        return self.records.get(fn_name)
