"""junctiond — the paper's contribution (§3/§4): a function manager that
replaces containerd, deploying faasd components and user functions inside
Junction instances.

Responsibilities (paper §4): configure instance networking, deploy
instances via ``junction_run``, monitor running state.  junctiond itself
is the only component outside a Junction instance (it must spawn new host
processes).  Scale-up of a function either (a) adds uProcs to an existing
instance (runtimes without native parallelism, e.g. Python), (b) raises
the instance's core cap, or (c) spawns an isolated sibling instance.

As an :class:`~repro.core.backends.ExecutionBackend` it also owns the
bypass datapath bundle: the centralized polling scheduler (one reserved
core), the Junction netstack costs, and the Junction instances hosting
the faasd gateway/provider services themselves (paper §3: "Junction
instances host not only the function code but also the services in the
FaaS runtime").
"""
from __future__ import annotations

import dataclasses
from typing import Generator, List, Optional

from repro.core.backends import (ColdStartModel, ExecutionBackend,
                                 register_backend)
from repro.core.junction import JunctionInstance
from repro.core.latency import (JUNCTION_INSTANCE_INIT_MS, JUNCTION_RUNTIME,
                                JUNCTION_STACK, JUNCTION_UPROC_SPAWN_MS,
                                JUNCTIOND_QUERY_MS)
from repro.core.scheduler import JunctionScheduler, PollingModel


@dataclasses.dataclass
class FunctionRecord:
    name: str
    instances: List[JunctionInstance]
    ip: str
    port: int
    replicas: int = 1
    isolated: bool = False      # replica = sibling instance, not uProc

    @property
    def ready(self) -> bool:
        return all(i.ready for i in self.instances)


@register_backend
class Junctiond(ExecutionBackend):
    name = "junctiond"
    runtime = JUNCTION_RUNTIME
    stack_costs = JUNCTION_STACK
    coldstart = ColdStartModel(
        deploy_ms=JUNCTION_INSTANCE_INIT_MS,
        scale_factor=JUNCTION_UPROC_SPAWN_MS / JUNCTION_INSTANCE_INIT_MS,
        query_ms=JUNCTIOND_QUERY_MS)

    # -- wiring ----------------------------------------------------------
    def _build_scheduler(self, polling_model: PollingModel) -> JunctionScheduler:
        scheduler = JunctionScheduler(self.sim, self.cores, polling_model)
        scheduler.run()
        return scheduler

    def _start_services(self) -> None:
        # the runtime services themselves live in Junction instances
        self._svc_gateway = JunctionInstance(self.sim, "svc/gateway",
                                             max_cores=4)
        self._svc_provider = JunctionInstance(self.sim, "svc/provider",
                                              max_cores=4)
        self._svc_gateway.ready = self._svc_provider.ready = True
        self.scheduler.register(self._svc_gateway)
        self.scheduler.register(self._svc_provider)

    # -- lifecycle -------------------------------------------------------
    def deploy(self, fn_name: str, *, scale: int = 1, max_cores: int = 2,
               isolate_replicas: bool = False) -> Generator:
        """Process: spawn Junction instance(s) via `junction_run` and
        configure networking.  Yields until ready."""
        self.remove(fn_name)      # redeploy releases the old instances
        insts: List[JunctionInstance] = []
        n_instances = scale if isolate_replicas else 1
        for i in range(n_instances):
            inst = yield from self._spawn_instance(fn_name, i, max_cores)
            if not isolate_replicas:
                for j in range(1, scale):
                    inst.spawn_uproc(f"{fn_name}/uproc{j}")
            insts.append(inst)
        self.records[fn_name] = FunctionRecord(
            name=fn_name, instances=insts, ip=f"10.62.0.{len(self.records) + 2}",
            port=8080, replicas=scale, isolated=isolate_replicas)
        self.deploys += 1

    def _spawn_instance(self, fn_name: str, idx: int,
                        max_cores: int) -> Generator:
        inst = JunctionInstance(self.sim, f"{fn_name}#{idx}",
                                max_cores=max_cores)
        # paper §5: 3.4 ms measured instance init (single-threaded)
        yield self.sim.timeout(self.coldstart.deploy_seconds)
        inst.spawn_uproc(f"{fn_name}/uproc0")
        inst.ready = True
        self.scheduler.register(inst)
        return inst

    def scale(self, fn_name: str, replicas: int) -> Generator:
        rec = self._require(fn_name)
        if rec.isolated:
            # replica = sibling instance: spawn new ones at full instance
            # init cost, reap extras (keeping one warm, as the shared path
            # keeps its instance) and release their scheduler registrations
            while len(rec.instances) < replicas:
                inst = yield from self._spawn_instance(
                    fn_name, len(rec.instances), rec.instances[0].max_cores)
                rec.instances.append(inst)
            for inst in rec.instances[max(1, replicas):]:
                self.scheduler.unregister(inst)
            del rec.instances[max(1, replicas):]
        else:
            inst = rec.instances[0]
            while len(inst.uprocs) < replicas:
                inst.spawn_uproc(f"{fn_name}/uproc{len(inst.uprocs)}")
                # uProc spawn inside the libOS
                yield self.sim.timeout(self.coldstart.scale_seconds)
            # scale-down reaps uProcs, keeping one warm like the isolated
            # path keeps an instance (scale-to-zero = warm floor of one)
            del inst.uprocs[max(1, replicas):]
        rec.replicas = replicas

    def remove(self, fn_name: str) -> None:
        rec = self.records.pop(fn_name, None)
        if rec:
            for inst in rec.instances:
                self.scheduler.unregister(inst)

    # query(): inherited control-plane state query at JUNCTIOND_QUERY_MS
    # (what the provider cache avoids, paper §4).

    def lookup(self, fn_name: str) -> Optional[FunctionRecord]:
        return self.records.get(fn_name)
