"""Calibrated per-operation cost constants for the two datapaths.

Values are literature-grounded (µs scale):

* syscall entry/exit ~0.5–1.5 µs (post-KPTI x86) — [Junction §2, IX, Demikernel]
* kernel TCP tx/rx processing ~3–8 µs/packet — [mTCP, IX]
* interrupt + softirq + thread wakeup (ctx switch + run-queue delay)
  ~10–25 µs under background load — [Caladan §2]
* CFS/GC/timer "hiccups" of 1–3 ms with small probability drive the
  kernel-path tail — [Shinjuku, Caladan]
* Junction: user-space stack ~1 µs, NIC doorbell/DMA ~0.6 µs, centralized
  scheduler poll pickup <0.5 µs, preemption bounded — [Junction §4/§5]
* Junction instance cold init = 3.4 ms — **measured in the paper (§5)**.

The *relative* end-to-end numbers these produce are validated against the
paper's claims in benchmarks/fig5_latency.py and fig6_load.py; see
EXPERIMENTS.md §Paper-validation for the calibration log.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StackCosts:
    """One-way message costs for one network traversal."""
    name: str
    # latency-only components (seconds)
    send_lat_us: float        # syscall + tx processing (sender side, also CPU)
    wire_us: float            # NIC + wire + switch
    rx_lat_us: float          # rx processing before app sees data
    wakeup_us: float          # interrupt->softirq->scheduler wakeup (kernel)
                              # or poll pickup + uthread dispatch (junction)
    # CPU consumed on the host per message (seconds of core time)
    tx_cpu_us: float
    rx_cpu_us: float
    wakeup_cpu_us: float      # context switch cost (kernel) / dispatch (junction)
    per_kb_us: float          # serialization+copy per KiB (zero-copy for junction)
    # tail behaviour
    jitter_sigma: float       # lognormal sigma on processing
    hiccup_p: float           # P(scheduling/GC hiccup) per message
    hiccup_lo_ms: float
    hiccup_hi_ms: float


KERNEL_STACK = StackCosts(
    name="kernel",
    send_lat_us=5.0,      # sendmsg syscall 1.0 + TCP/IP tx 4.0
    wire_us=1.0,
    rx_lat_us=6.0,        # softirq rx processing
    wakeup_us=15.0,       # interrupt + wake + run-queue delay
    tx_cpu_us=5.0, rx_cpu_us=6.0, wakeup_cpu_us=3.0,
    per_kb_us=0.6,
    jitter_sigma=0.30,
    hiccup_p=0.010, hiccup_lo_ms=0.7, hiccup_hi_ms=2.2,
)

JUNCTION_STACK = StackCosts(
    name="junction",
    send_lat_us=1.0,      # user-space stack, function-call "syscall"
    wire_us=1.0,
    rx_lat_us=0.6,        # DMA into user memory
    wakeup_us=0.7,        # poll pickup + uthread dispatch
    tx_cpu_us=0.9, rx_cpu_us=0.5, wakeup_cpu_us=0.3,
    per_kb_us=0.15,       # zero-copy path
    jitter_sigma=0.15,
    hiccup_p=0.009, hiccup_lo_ms=0.1, hiccup_hi_ms=0.65,
)


@dataclasses.dataclass(frozen=True)
class RuntimeCosts:
    """Per-component application processing (µs of CPU on the critical
    path) and function-execution overheads."""
    name: str
    gateway_us: float          # auth + route + proxy (Go, HTTP/2)
    provider_us: float         # resolve + proxy
    watchdog_us: float         # of-watchdog style in-instance request fanout
    exec_syscall_overhead_us: float   # OS interactions during function body
    exec_hiccup_p: float       # hiccup during execution (GC/CFS preempt)
    exec_hiccup_lo_ms: float
    exec_hiccup_hi_ms: float
    app_jitter_sigma: float
    # scheduling-thrash model: effective CPU multiplier grows with
    # (runnable backlog / cores); bounded.  Kernel CFS thrashes (cache
    # pollution, migrations); Junction runs-to-completion.
    thrash_coeff: float
    thrash_cap: float
    # CPU burned per request OFF the critical path (GC cycles, goroutine
    # scheduler, logging, HTTP/2 framing, interrupt/softirq handling at
    # load) as a multiple of the critical-path processing time.  This is
    # what caps throughput long before latency shows it; Go orchestration
    # services measure 3-5x (pprof on faasd's gateway/provider); Junction's
    # runtime is lean (paper SS5: "compute optimizations ... reduction in
    # context switches").
    offpath_cpu_mult: float = 1.0
    # multiplier on the function body's pure-compute time: 1.0 for native
    # execution, >1 for sandboxes that recompile/interpret the workload
    # (Wasm AOT/JIT) or add per-instruction virtualisation drag.
    work_mult: float = 1.0


KERNEL_RUNTIME = RuntimeCosts(
    name="kernel",
    gateway_us=150.0, provider_us=200.0, watchdog_us=100.0,
    exec_syscall_overhead_us=58.0,
    exec_hiccup_p=0.025, exec_hiccup_lo_ms=0.8, exec_hiccup_hi_ms=2.8,
    app_jitter_sigma=0.30,
    thrash_coeff=0.9, thrash_cap=6.0,
    offpath_cpu_mult=5.0,
)

JUNCTION_RUNTIME = RuntimeCosts(
    name="junction",
    gateway_us=115.0, provider_us=155.0, watchdog_us=76.0,
    exec_syscall_overhead_us=5.0,
    # bounded preemption by the Junction scheduler still leaves a small
    # tail (core steals, quantum waits) — much shorter than CFS/GC.
    exec_hiccup_p=0.015, exec_hiccup_lo_ms=0.08, exec_hiccup_hi_ms=0.3,
    app_jitter_sigma=0.20,
    thrash_coeff=0.05, thrash_cap=1.15,
    offpath_cpu_mult=1.05,
)

# --- modeled backends from related work -----------------------------------
#
# Quark-style secure container runtime (arXiv:2309.12624): containers run
# on a user-space guest kernel (QKernel) behind a hypervisor boundary
# (QVisor).  Every syscall and every packet crosses the interception
# layer, so the kernel datapath costs grow; cold start pays a guest-kernel
# boot on top of the container create.

QUARK_STACK = StackCosts(
    name="quark",
    send_lat_us=9.0,      # sendmsg forwarded through QVisor + host TCP tx
    wire_us=1.0,
    rx_lat_us=10.0,       # host rx + virtio-style delivery into the guest
    wakeup_us=18.0,       # host interrupt + guest scheduler wakeup
    tx_cpu_us=8.0, rx_cpu_us=9.0, wakeup_cpu_us=4.0,
    per_kb_us=1.0,        # extra copy across the sandbox boundary
    jitter_sigma=0.32,
    hiccup_p=0.012, hiccup_lo_ms=0.7, hiccup_hi_ms=2.4,
)

QUARK_RUNTIME = RuntimeCosts(
    name="quark",
    gateway_us=172.0, provider_us=230.0, watchdog_us=115.0,
    exec_syscall_overhead_us=140.0,   # per-syscall interception tax
    exec_hiccup_p=0.028, exec_hiccup_lo_ms=0.8, exec_hiccup_hi_ms=3.0,
    app_jitter_sigma=0.32,
    thrash_coeff=0.95, thrash_cap=6.0,
    offpath_cpu_mult=5.5,
    work_mult=1.08,                   # guest-kernel virtualisation drag
)

# Wasm-style lightweight sandbox (arXiv:2010.07115, WasmEdge-class): the
# function is a Wasm module instantiated in-process.  Kernel network stack
# (no bypass), but instantiation is sub-ms and OS interactions go through
# a thin WASI shim; the compute itself pays a moderate AOT/JIT overhead.

WASM_RUNTIME = RuntimeCosts(
    name="wasm",
    gateway_us=150.0, provider_us=200.0, watchdog_us=70.0,
    exec_syscall_overhead_us=24.0,    # WASI shim, far fewer OS round-trips
    exec_hiccup_p=0.020, exec_hiccup_lo_ms=0.6, exec_hiccup_hi_ms=2.0,
    app_jitter_sigma=0.28,
    thrash_coeff=0.9, thrash_cap=6.0,
    offpath_cpu_mult=4.2,
    work_mult=1.35,                   # moderate compute overhead vs native
)

# Paper §5: measured Junction single-threaded instance init.
JUNCTION_INSTANCE_INIT_MS = 3.4
# Junctiond scale-up: one uProc spawn inside an already-running libOS.
JUNCTION_UPROC_SPAWN_MS = 0.2
# containerd cold start (container create + start, warm image) — literature
# (firecracker/containerd studies report 300–700 ms for Linux containers).
CONTAINERD_COLDSTART_MS = 450.0
# containerd control-plane state query (the thing the provider cache
# removes from the critical path; paper §4 notes it can exceed the
# function execution time itself).
CONTAINERD_QUERY_MS = 1.8
JUNCTIOND_QUERY_MS = 0.15
# Quark: container create + guest kernel (QKernel) boot behind QVisor.
QUARK_COLDSTART_MS = 620.0
QUARK_QUERY_MS = 2.1
# Wasm: module instantiation from a compiled image — sub-ms.
WASM_COLDSTART_MS = 0.6
WASM_QUERY_MS = 0.4

# The benchmark function: AES-128-CTR over a 600-byte input (vSwarm),
# pure compute time on one 2.2 GHz Xeon core (~0.5 cycles/byte with AES-NI
# would be ~0.14 µs; vSwarm's Go implementation without AES-NI batching,
# including marshalling, measures ~tens of µs).  We use the measured-ish
# vSwarm Go figure.
AES_600B_WORK_US = 95.0
