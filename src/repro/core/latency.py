"""Calibrated per-operation cost constants for the two datapaths.

Values are literature-grounded (µs scale):

* syscall entry/exit ~0.5–1.5 µs (post-KPTI x86) — [Junction §2, IX, Demikernel]
* kernel TCP tx/rx processing ~3–8 µs/packet — [mTCP, IX]
* interrupt + softirq + thread wakeup (ctx switch + run-queue delay)
  ~10–25 µs under background load — [Caladan §2]
* CFS/GC/timer "hiccups" of 1–3 ms with small probability drive the
  kernel-path tail — [Shinjuku, Caladan]
* Junction: user-space stack ~1 µs, NIC doorbell/DMA ~0.6 µs, centralized
  scheduler poll pickup <0.5 µs, preemption bounded — [Junction §4/§5]
* Junction instance cold init = 3.4 ms — **measured in the paper (§5)**.

The *relative* end-to-end numbers these produce are validated against the
paper's claims in benchmarks/fig5_latency.py and fig6_load.py; see
EXPERIMENTS.md §Paper-validation for the calibration log.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StackCosts:
    """One-way message costs for one network traversal."""
    name: str
    # latency-only components (seconds)
    send_lat_us: float        # syscall + tx processing (sender side, also CPU)
    wire_us: float            # NIC + wire + switch
    rx_lat_us: float          # rx processing before app sees data
    wakeup_us: float          # interrupt->softirq->scheduler wakeup (kernel)
                              # or poll pickup + uthread dispatch (junction)
    # CPU consumed on the host per message (seconds of core time)
    tx_cpu_us: float
    rx_cpu_us: float
    wakeup_cpu_us: float      # context switch cost (kernel) / dispatch (junction)
    per_kb_us: float          # serialization+copy per KiB (zero-copy for junction)
    # tail behaviour
    jitter_sigma: float       # lognormal sigma on processing
    hiccup_p: float           # P(scheduling/GC hiccup) per message
    hiccup_lo_ms: float
    hiccup_hi_ms: float


KERNEL_STACK = StackCosts(
    name="kernel",
    send_lat_us=5.0,      # sendmsg syscall 1.0 + TCP/IP tx 4.0
    wire_us=1.0,
    rx_lat_us=6.0,        # softirq rx processing
    wakeup_us=15.0,       # interrupt + wake + run-queue delay
    tx_cpu_us=5.0, rx_cpu_us=6.0, wakeup_cpu_us=3.0,
    per_kb_us=0.6,
    jitter_sigma=0.30,
    hiccup_p=0.010, hiccup_lo_ms=0.7, hiccup_hi_ms=2.2,
)

JUNCTION_STACK = StackCosts(
    name="junction",
    send_lat_us=1.0,      # user-space stack, function-call "syscall"
    wire_us=1.0,
    rx_lat_us=0.6,        # DMA into user memory
    wakeup_us=0.7,        # poll pickup + uthread dispatch
    tx_cpu_us=0.9, rx_cpu_us=0.5, wakeup_cpu_us=0.3,
    per_kb_us=0.15,       # zero-copy path
    jitter_sigma=0.15,
    hiccup_p=0.009, hiccup_lo_ms=0.1, hiccup_hi_ms=0.65,
)


@dataclasses.dataclass(frozen=True)
class RuntimeCosts:
    """Per-component application processing (µs of CPU on the critical
    path) and function-execution overheads."""
    name: str
    gateway_us: float          # auth + route + proxy (Go, HTTP/2)
    provider_us: float         # resolve + proxy
    watchdog_us: float         # of-watchdog style in-instance request fanout
    exec_syscall_overhead_us: float   # OS interactions during function body
    exec_hiccup_p: float       # hiccup during execution (GC/CFS preempt)
    exec_hiccup_lo_ms: float
    exec_hiccup_hi_ms: float
    app_jitter_sigma: float
    # scheduling-thrash model: effective CPU multiplier grows with
    # (runnable backlog / cores); bounded.  Kernel CFS thrashes (cache
    # pollution, migrations); Junction runs-to-completion.
    thrash_coeff: float
    thrash_cap: float
    # CPU burned per request OFF the critical path (GC cycles, goroutine
    # scheduler, logging, HTTP/2 framing, interrupt/softirq handling at
    # load) as a multiple of the critical-path processing time.  This is
    # what caps throughput long before latency shows it; Go orchestration
    # services measure 3-5x (pprof on faasd's gateway/provider); Junction's
    # runtime is lean (paper SS5: "compute optimizations ... reduction in
    # context switches").
    offpath_cpu_mult: float = 1.0
    # multiplier on the function body's pure-compute time: 1.0 for native
    # execution, >1 for sandboxes that recompile/interpret the workload
    # (Wasm AOT/JIT) or add per-instruction virtualisation drag.
    work_mult: float = 1.0


KERNEL_RUNTIME = RuntimeCosts(
    name="kernel",
    gateway_us=150.0, provider_us=200.0, watchdog_us=100.0,
    exec_syscall_overhead_us=58.0,
    exec_hiccup_p=0.025, exec_hiccup_lo_ms=0.8, exec_hiccup_hi_ms=2.8,
    app_jitter_sigma=0.30,
    thrash_coeff=0.9, thrash_cap=6.0,
    offpath_cpu_mult=5.0,
)

JUNCTION_RUNTIME = RuntimeCosts(
    name="junction",
    gateway_us=115.0, provider_us=155.0, watchdog_us=76.0,
    exec_syscall_overhead_us=5.0,
    # bounded preemption by the Junction scheduler still leaves a small
    # tail (core steals, quantum waits) — much shorter than CFS/GC.
    exec_hiccup_p=0.015, exec_hiccup_lo_ms=0.08, exec_hiccup_hi_ms=0.3,
    app_jitter_sigma=0.20,
    thrash_coeff=0.05, thrash_cap=1.15,
    offpath_cpu_mult=1.05,
)

# --- modeled backends from related work -----------------------------------
#
# Quark-style secure container runtime (arXiv:2309.12624): containers run
# on a user-space guest kernel (QKernel) behind a hypervisor boundary
# (QVisor).  Every syscall and every packet crosses the interception
# layer, so the kernel datapath costs grow; cold start pays a guest-kernel
# boot on top of the container create.

QUARK_STACK = StackCosts(
    name="quark",
    send_lat_us=9.0,      # sendmsg forwarded through QVisor + host TCP tx
    wire_us=1.0,
    rx_lat_us=10.0,       # host rx + virtio-style delivery into the guest
    wakeup_us=18.0,       # host interrupt + guest scheduler wakeup
    tx_cpu_us=8.0, rx_cpu_us=9.0, wakeup_cpu_us=4.0,
    per_kb_us=1.0,        # extra copy across the sandbox boundary
    jitter_sigma=0.32,
    hiccup_p=0.012, hiccup_lo_ms=0.7, hiccup_hi_ms=2.4,
)

QUARK_RUNTIME = RuntimeCosts(
    name="quark",
    gateway_us=172.0, provider_us=230.0, watchdog_us=115.0,
    exec_syscall_overhead_us=140.0,   # per-syscall interception tax
    exec_hiccup_p=0.028, exec_hiccup_lo_ms=0.8, exec_hiccup_hi_ms=3.0,
    app_jitter_sigma=0.32,
    thrash_coeff=0.95, thrash_cap=6.0,
    offpath_cpu_mult=5.5,
    work_mult=1.08,                   # guest-kernel virtualisation drag
)

# Wasm-style lightweight sandbox (arXiv:2010.07115, WasmEdge-class): the
# function is a Wasm module instantiated in-process.  Kernel network stack
# (no bypass), but instantiation is sub-ms and OS interactions go through
# a thin WASI shim; the compute itself pays a moderate AOT/JIT overhead.

WASM_RUNTIME = RuntimeCosts(
    name="wasm",
    gateway_us=150.0, provider_us=200.0, watchdog_us=70.0,
    exec_syscall_overhead_us=24.0,    # WASI shim, far fewer OS round-trips
    exec_hiccup_p=0.020, exec_hiccup_lo_ms=0.6, exec_hiccup_hi_ms=2.0,
    app_jitter_sigma=0.28,
    thrash_coeff=0.9, thrash_cap=6.0,
    offpath_cpu_mult=4.2,
    work_mult=1.35,                   # moderate compute overhead vs native
)

# Firecracker-style microVM (NSDI '20): a minimal VMM boots a slim guest
# kernel per function.  The datapath rides virtio-net through TWO stacks
# (guest kernel TCP + host tap forwarding), so warm costs sit just above
# plain containers; the cold path is where the design moves — a full
# microVM boot is ~125 ms, but restoring a pre-warmed snapshot takes
# single-digit ms (the serverless snapshot-restore literature, e.g.
# arXiv:2202.09251 and the unikernel comparisons in arXiv:2403.00515,
# report 3–10 ms restores).

FIRECRACKER_STACK = StackCosts(
    name="firecracker",
    send_lat_us=7.0,      # guest TCP tx + virtio-net + host tap forward
    wire_us=1.0,
    rx_lat_us=8.0,        # host rx + virtio delivery into the guest
    wakeup_us=16.5,       # host interrupt + guest vCPU wakeup
    tx_cpu_us=6.5, rx_cpu_us=7.5, wakeup_cpu_us=3.5,
    per_kb_us=0.8,        # extra copy across the virtio boundary
    jitter_sigma=0.31,
    hiccup_p=0.011, hiccup_lo_ms=0.7, hiccup_hi_ms=2.3,
)

FIRECRACKER_RUNTIME = RuntimeCosts(
    name="firecracker",
    gateway_us=158.0, provider_us=212.0, watchdog_us=104.0,
    exec_syscall_overhead_us=75.0,    # mostly-native guest syscalls + VM exits
    exec_hiccup_p=0.026, exec_hiccup_lo_ms=0.8, exec_hiccup_hi_ms=2.8,
    app_jitter_sigma=0.30,
    thrash_coeff=0.92, thrash_cap=6.0,
    offpath_cpu_mult=5.1,
    work_mult=1.02,                   # near-native compute inside the guest
)

# gVisor-style sandboxed runtime (runsc): the Sentry, a user-space kernel
# written in Go, intercepts every syscall and owns a user-space netstack.
# With the KVM platform the interception is a lightweight VM exit; with
# the ptrace platform every syscall costs two context switches, several
# times slower (gVisor's own platform guide and the published syscall
# microbenchmarks).  Warm costs land between containerd and quark.

GVISOR_KVM_STACK = StackCosts(
    name="gvisor-kvm",
    send_lat_us=8.0,      # Sentry netstack tx + host forward
    wire_us=1.0,
    rx_lat_us=9.0,        # host rx + netstack delivery
    wakeup_us=17.0,       # host interrupt + Sentry goroutine wakeup
    tx_cpu_us=7.0, rx_cpu_us=8.0, wakeup_cpu_us=4.0,
    per_kb_us=0.9,        # copy through the Sentry
    jitter_sigma=0.32,
    hiccup_p=0.012,       # Go GC pauses inside the Sentry
    hiccup_lo_ms=0.7, hiccup_hi_ms=2.4,
)

GVISOR_KVM_RUNTIME = RuntimeCosts(
    name="gvisor-kvm",
    gateway_us=165.0, provider_us=222.0, watchdog_us=110.0,
    exec_syscall_overhead_us=112.0,   # Sentry interception via KVM exits
    exec_hiccup_p=0.027, exec_hiccup_lo_ms=0.8, exec_hiccup_hi_ms=2.9,
    app_jitter_sigma=0.31,
    thrash_coeff=0.93, thrash_cap=6.0,
    offpath_cpu_mult=5.3,
    work_mult=1.05,
)

GVISOR_PTRACE_STACK = StackCosts(
    name="gvisor-ptrace",
    send_lat_us=11.0,     # every netstack hop pays ptrace stops
    wire_us=1.0,
    rx_lat_us=12.0,
    wakeup_us=19.0,
    tx_cpu_us=9.5, rx_cpu_us=10.5, wakeup_cpu_us=4.5,
    per_kb_us=1.1,
    jitter_sigma=0.33,
    hiccup_p=0.013, hiccup_lo_ms=0.7, hiccup_hi_ms=2.5,
)

GVISOR_PTRACE_RUNTIME = RuntimeCosts(
    name="gvisor-ptrace",
    gateway_us=170.0, provider_us=228.0, watchdog_us=112.0,
    exec_syscall_overhead_us=230.0,   # two context switches per syscall
    exec_hiccup_p=0.028, exec_hiccup_lo_ms=0.8, exec_hiccup_hi_ms=3.0,
    app_jitter_sigma=0.33,
    thrash_coeff=0.95, thrash_cap=6.0,
    offpath_cpu_mult=5.6,
    work_mult=1.06,
)

# Paper §5: measured Junction single-threaded instance init.
JUNCTION_INSTANCE_INIT_MS = 3.4
# Junctiond scale-up: one uProc spawn inside an already-running libOS.
JUNCTION_UPROC_SPAWN_MS = 0.2
# containerd cold start (container create + start, warm image) — literature
# (firecracker/containerd studies report 300–700 ms for Linux containers).
CONTAINERD_COLDSTART_MS = 450.0
# containerd control-plane state query (the thing the provider cache
# removes from the critical path; paper §4 notes it can exceed the
# function execution time itself).
CONTAINERD_QUERY_MS = 1.8
JUNCTIOND_QUERY_MS = 0.15
# Quark: container create + guest kernel (QKernel) boot behind QVisor.
QUARK_COLDSTART_MS = 620.0
QUARK_QUERY_MS = 2.1
# Wasm: module instantiation from a compiled image — sub-ms.
WASM_COLDSTART_MS = 0.6
WASM_QUERY_MS = 0.4
# Firecracker: full microVM boot (VMM init + guest kernel + init) vs
# restoring a pre-warmed memory/device snapshot of the booted guest.
# Warming the snapshot is not free: the first boot also pauses the VM
# and serializes guest memory + device state to disk before the cache
# can serve restores.
FIRECRACKER_BOOT_MS = 125.0
FIRECRACKER_SNAPSHOT_SAVE_MS = 60.0
FIRECRACKER_RESTORE_MS = 5.0
FIRECRACKER_QUERY_MS = 1.6
# gVisor: runsc create + Sentry boot — no guest Linux kernel to bring up,
# so it lands just under a containerd cold start (and well under quark's
# guest-kernel boot).
GVISOR_COLDSTART_MS = 400.0
GVISOR_QUERY_MS = 1.9

# The benchmark function: AES-128-CTR over a 600-byte input (vSwarm),
# pure compute time on one 2.2 GHz Xeon core (~0.5 cycles/byte with AES-NI
# would be ~0.14 µs; vSwarm's Go implementation without AES-NI batching,
# including marshalling, measures ~tens of µs).  We use the measured-ish
# vSwarm Go figure.
AES_600B_WORK_US = 95.0
