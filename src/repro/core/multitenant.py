"""Multi-tenant workload (paper §1 motivation: "most functions are not
frequently invoked" [Shahrad et al.]) — a server hosting N functions with
Zipf-distributed popularity, where the polling-resource question decides
how many functions a worker can host at all.

For the DPDK-style per-instance polling model, hosting N isolated
functions burns N cores; the Junction centralized scheduler burns one.
This module drives both configurations with the same Zipf invocation
stream and reports per-popularity-tier latency + capacity.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.faas import FaasdRuntime, FunctionSpec
from repro.core.scheduler import PollingModel
from repro.core.simulator import Simulator
from repro.core.workload import LatencySummary


@dataclasses.dataclass
class MultiTenantResult:
    n_functions: int
    hosted: int                  # functions actually deployable
    cores_for_work: int
    overall: LatencySummary
    hot_tier: LatencySummary     # top-10% functions
    cold_tier: LatencySummary    # bottom-50% functions


def run_zipf_workload(backend: str, *, n_functions: int = 64,
                      total_rps: float = 2000.0, duration_s: float = 1.0,
                      zipf_a: float = 1.5, n_cores: int = 36,
                      polling: PollingModel = PollingModel.CENTRALIZED,
                      seed: int = 0) -> MultiTenantResult:
    sim = Simulator(seed=seed)
    rt = FaasdRuntime(sim, backend=backend, n_cores=n_cores,
                      polling_model=polling)

    # deploy until cores run out (per-instance polling caps this)
    hosted = 0
    for i in range(n_functions):
        if rt.scheduler is not None and rt.cores.n_cores <= 1:
            break
        rt.deploy_blocking(FunctionSpec(name=f"f{i}"))
        hosted += 1

    ranks = np.arange(1, hosted + 1, dtype=np.float64)
    popularity = ranks ** (-zipf_a)
    popularity /= popularity.sum()

    per_fn_records: Dict[str, List[float]] = {f"f{i}": [] for i in range(hosted)}

    def arrivals():
        t_end = sim.now + duration_s
        while sim.now < t_end:
            yield sim.timeout(sim.exponential(1.0 / total_rps))
            fn = f"f{int(sim.rng.choice(hosted, p=popularity))}"

            def one(fn=fn):
                rec = yield from rt.invoke(fn)
                per_fn_records[fn].append(rec.e2e * 1e3)

            sim.process(one())

    sim.process(arrivals())
    sim.run(until=sim.now + duration_s + 1.5)

    all_lat = [l for ls in per_fn_records.values() for l in ls]
    hot = [l for i in range(max(1, hosted // 10))
           for l in per_fn_records[f"f{i}"]]
    cold = [l for i in range(hosted // 2, hosted)
            for l in per_fn_records[f"f{i}"]]
    return MultiTenantResult(
        n_functions=n_functions, hosted=hosted,
        cores_for_work=rt.cores.n_cores,
        overall=LatencySummary.of(all_lat),
        hot_tier=LatencySummary.of(hot),
        cold_tier=LatencySummary.of(cold or all_lat),
    )
