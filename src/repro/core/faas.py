"""faasd runtime model: gateway → provider → function instance (paper §2.1.1).

Every invocation traverses the gateway and the provider before reaching
the sandbox running the function (3 gRPC legs, responses flowing back the
same path).  Where the orchestration services run and which datapath a
message rides is entirely the :class:`~repro.core.backends.ExecutionBackend`'s
business: the runtime composes with whatever bundle the backend provides
(cost tables, core pool, optional scheduler, netstack, lifecycle) and has
no backend-specific branches.  Backends resolve by registry name or can
be passed as ready instances.

The provider optionally caches function metadata (replica count, IP,
port), keeping the backend's control plane off the warm critical path
(paper §4; applied to EVERY backend for a fair comparison, as in the
paper).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Generator, List, Optional, Tuple, Union

import numpy as np

from repro.core.backends import ExecutionBackend, resolve_backend
from repro.core.latency import AES_600B_WORK_US, RuntimeCosts
from repro.core.scheduler import PollingModel
from repro.core.simulator import Simulator


@dataclasses.dataclass
class FunctionSpec:
    """A deployable FaaS function."""
    name: str
    work_us: Union[float, Callable[[], float]] = AES_600B_WORK_US
    payload_bytes: int = 600
    response_bytes: int = 628          # input + AES-CTR overhead
    scale: int = 1
    max_cores: int = 2

    def work_seconds(self) -> float:
        w = self.work_us() if callable(self.work_us) else self.work_us
        return w * 1e-6


@dataclasses.dataclass(slots=True)
class InvocationRecord:
    fn: str
    t_arrival: float
    t_start_exec: float = 0.0
    t_end_exec: float = 0.0
    t_done: float = 0.0
    cold: bool = False

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def exec_latency(self) -> float:
        return self.t_end_exec - self.t_start_exec


@dataclasses.dataclass(frozen=True)
class InvocationPlan:
    """Hop-compressed invocation template for the event-heap driver.

    The generator path (:meth:`FaasdRuntime.invoke`) walks 14 CPU
    segments and 8 latency gaps per request; the flat driver compresses
    that chain to the station level — 3 contiguous-CPU *holds* separated
    by 2 pure-latency *gaps*, plus one merged off-path CPU job — so a
    request costs ~4 heap events instead of ~40 generator resumes.
    Component sums are preserved exactly: uncontended end-to-end latency
    and total CPU per request (hence capacity/knee locations) match the
    generator path; only the intra-request interleaving is coarser.

    Stations (CPU, acquired through the core pool with thrash):
      H0 ingress: gateway + both request-side proxy legs (gw->provider,
         provider->instance tx/rx and app costs)
      H1 exec: rx + watchdog + exec body + tx(response leg 1)
      H2 egress: both response-side proxy legs + gateway response
    Gaps (latency only, between consecutive stations): the summed send
    jitter + wire + rx/wakeup jitter + tail hiccups of the legs each
    station absorbed; the exec hiccup rides the egress gap.  The
    off-path job merges the five per-_app async CPU chunks into one
    (spawned at H0 completion, backlog weight 5 so the thrash signal
    sees the same queued-entry pressure as five legacy jobs).
    """

    fn: str
    app_medians_us: Tuple[float, ...]    # gw, provider, watchdog, p*.35, g*.35
    app_sigma: float
    tx_cpu_s: Tuple[float, ...]          # per net leg, seconds
    rx_cpu_s: Tuple[float, ...]
    send_lat_us: float
    rx_wake_us: float
    wire_s: float
    net_sigma: float
    net_hiccup_p: float
    net_hiccup_lo_s: float
    net_hiccup_hi_s: float
    work_us: Union[float, Callable[[], float]]
    work_mult: float
    overhead_us: float
    exec_hiccup_p: float
    exec_hiccup_lo_s: float
    exec_hiccup_hi_s: float
    offpath_mult: float
    stack_cpu_s: float                   # total netstack CPU per request

    OFFPATH_BACKLOG_WEIGHT = 5
    # a queued station wait stands for the queue pressure of the several
    # finer-grained legacy segment waits it merged: without the extra
    # weight the thrash signal under-reads near saturation and the
    # compressed plan's SLO knees drift one search step (~9%) above the
    # generator engine's (calibrated against the 6-backend knee suite)
    STATION_BACKLOG_WEIGHT = 2

    def _work_batch(self, rng: np.random.Generator, m: int) -> np.ndarray:
        w = self.work_us
        if callable(w):
            batch = getattr(w, "sample", None)
            if batch is not None:
                return np.asarray(batch(m), dtype=np.float64) * 1e-6
            return np.array([w() for _ in range(m)], dtype=np.float64) * 1e-6
        return np.full(m, w * 1e-6)

    def sample(self, rng: np.random.Generator, m: int):
        """Vectorized per-request variates for ``m`` invocations.

        Returns ``(holds, gaps, offpath, exec_s, n_net_hiccups)`` with
        ``holds`` of shape (m, 3), ``gaps`` (m, 2), ``offpath``/
        ``exec_s`` (m,) — all in seconds."""
        sig = self.app_sigma
        apps = [rng.lognormal(math.log(mu), sig, m) * 1e-6
                for mu in self.app_medians_us]
        work = self._work_batch(rng, m) * self.work_mult
        overhead = rng.lognormal(math.log(self.overhead_us), sig, m) * 1e-6
        ehic = np.zeros(m)
        hit = rng.random(m) < self.exec_hiccup_p
        ehic[hit] = rng.uniform(self.exec_hiccup_lo_s, self.exec_hiccup_hi_s,
                                int(hit.sum()))
        holds = np.empty((m, 3))
        holds[:, 0] = (apps[0] + self.tx_cpu_s[0]
                       + self.rx_cpu_s[0] + apps[1] + self.tx_cpu_s[1])
        holds[:, 1] = (self.rx_cpu_s[1] + apps[2] + work + overhead
                       + self.tx_cpu_s[2])
        holds[:, 2] = (self.rx_cpu_s[2] + apps[3] + self.tx_cpu_s[3]
                       + self.rx_cpu_s[3] + apps[4])
        # each compressed gap absorbs two of the chain's four net legs
        # (ingress: legs 0+1, egress: legs 2+3) — sums preserved
        gaps = np.empty((m, 2))
        n_hic = 0
        for k in range(2):
            send = rng.lognormal(math.log(self.send_lat_us),
                                 self.net_sigma, (2, m)).sum(axis=0) * 1e-6
            rx = rng.lognormal(math.log(self.rx_wake_us),
                               self.net_sigma, (2, m)).sum(axis=0) * 1e-6
            gaps[:, k] = send + 2.0 * self.wire_s + rx
            hit = rng.random((2, m)) < self.net_hiccup_p
            nh = int(hit.sum())
            if nh:
                extra = np.zeros((2, m))
                extra[hit] = rng.uniform(self.net_hiccup_lo_s,
                                         self.net_hiccup_hi_s, nh)
                gaps[:, k] += extra.sum(axis=0)
                n_hic += nh
        gaps[:, 1] += ehic
        offpath = ((apps[0] + apps[1] + apps[2] + apps[3] + apps[4])
                   * (self.offpath_mult - 1.0))
        exec_s = work + overhead + ehic
        return holds, gaps, offpath, exec_s, n_hic

    def sample_exec(self, rng: np.random.Generator, m: int):
        """Exec-only variates for ``m`` *fused* intra-sandbox handoffs:
        a fused chain callee skips the gateway and netstack stations
        entirely, so only its function body is charged — ``(cpu, hic)``
        where ``cpu`` is CPU held on the exec station (work + syscall
        overhead) and ``hic`` is tail-hiccup latency, both (m,) in
        seconds."""
        work = self._work_batch(rng, m) * self.work_mult
        overhead = rng.lognormal(math.log(self.overhead_us),
                                 self.app_sigma, m) * 1e-6
        hic = np.zeros(m)
        hit = rng.random(m) < self.exec_hiccup_p
        hic[hit] = rng.uniform(self.exec_hiccup_lo_s, self.exec_hiccup_hi_s,
                               int(hit.sum()))
        return work + overhead, hic


class FaasdRuntime:
    """One worker node running the full faasd stack."""

    def __init__(self, sim: Simulator, *,
                 backend: Union[str, ExecutionBackend] = "junctiond",
                 n_cores: Optional[int] = None, provider_cache: bool = True,
                 polling_model: Optional[PollingModel] = None):
        self.sim = sim
        self.provider_cache = provider_cache
        self.backend = resolve_backend(backend, sim, n_cores=n_cores,
                                       polling_model=polling_model)
        self.backend_name = self.backend.name
        self.runtime: RuntimeCosts = self.backend.runtime
        self.cores = self.backend.cores
        self.scheduler = self.backend.scheduler
        self.stack = self.backend.stack
        self.manager = self.backend     # lifecycle ops go to the backend
        self.functions: Dict[str, FunctionSpec] = {}
        self._cache: Dict[str, object] = {}
        self.records: List[InvocationRecord] = []
        self.cache_hits = 0
        self.cache_misses = 0
        self.rejected = 0

    # -- deployment -------------------------------------------------------
    def deploy(self, spec: FunctionSpec) -> Generator:
        self.functions[spec.name] = spec
        yield from self.manager.deploy(spec.name, scale=spec.scale,
                                       max_cores=spec.max_cores)
        if self.provider_cache:
            self._cache[spec.name] = self.manager.lookup(spec.name)

    def deploy_blocking(self, spec: FunctionSpec) -> None:
        p = self.sim.process(self.deploy(spec))
        p.completion.callbacks.append(lambda _v: self.sim.stop())
        self.sim.run()
        assert p.done

    # -- helpers ------------------------------------------------------
    def _app(self, base_us: float) -> Generator:
        """Application processing: critical-path CPU with jitter, plus
        off-critical-path CPU (GC/softirq/bookkeeping) consumed
        asynchronously — it caps throughput without adding latency at low
        load."""
        t = self.sim.lognormal_us(base_us, self.runtime.app_jitter_sigma)
        yield from self.cores.consume(t)
        extra = t * (self.runtime.offpath_cpu_mult - 1.0)
        if extra > 0:
            self.sim.process(self.cores.consume(extra))

    def _exec_function(self, spec: FunctionSpec) -> Generator:
        """The function body: compute + OS interactions (+ tail hiccups)."""
        r = self.runtime
        work = spec.work_seconds() * r.work_mult
        overhead = self.sim.lognormal_us(r.exec_syscall_overhead_us,
                                         r.app_jitter_sigma)
        hic = 0.0
        if self.sim.rng.random() < r.exec_hiccup_p:
            hic = float(self.sim.rng.uniform(r.exec_hiccup_lo_ms,
                                             r.exec_hiccup_hi_ms)) * 1e-3
        yield from self.cores.consume(work + overhead)
        if hic:
            yield self.sim.timeout(hic)

    def _resolve(self, fn_name: str) -> Generator:
        """Provider resolving the function endpoint: cache or backend query."""
        if self.provider_cache and fn_name in self._cache:
            self.cache_hits += 1
            return self._cache[fn_name]
        self.cache_misses += 1
        rec = yield from self.manager.query(fn_name)
        if self.provider_cache:
            self._cache[fn_name] = rec
        return rec

    def invocation_plan(self, fn_name: str,
                        payload_scale: float = 1.0) -> InvocationPlan:
        """Compile the warm invocation chain for ``fn_name`` into the
        hop-compressed template the event-heap driver executes (see
        :class:`InvocationPlan`).  Message sizes and cost tables are
        resolved once here instead of per request.  ``payload_scale``
        scales the request payload (a chain hop's input is the upstream
        edge's transformed payload); the response rides unscaled."""
        spec = self.functions[fn_name]
        r = self.runtime
        c = self.stack.costs
        p = spec.payload_bytes * payload_scale
        sizes = (p + 220, p + 180,
                 spec.response_bytes + 120, spec.response_bytes + 120)
        tx = tuple((c.tx_cpu_us + c.per_kb_us * s / 1024.0) * 1e-6
                   for s in sizes)
        rx = tuple((c.rx_cpu_us + c.wakeup_cpu_us
                    + c.per_kb_us * s / 1024.0) * 1e-6 for s in sizes)
        return InvocationPlan(
            fn=fn_name,
            app_medians_us=(r.gateway_us, r.provider_us, r.watchdog_us,
                            r.provider_us * 0.35, r.gateway_us * 0.35),
            app_sigma=r.app_jitter_sigma,
            tx_cpu_s=tx, rx_cpu_s=rx,
            send_lat_us=c.send_lat_us,
            rx_wake_us=c.rx_lat_us + c.wakeup_us,
            wire_s=c.wire_us * 1e-6,
            net_sigma=c.jitter_sigma,
            net_hiccup_p=c.hiccup_p,
            net_hiccup_lo_s=c.hiccup_lo_ms * 1e-3,
            net_hiccup_hi_s=c.hiccup_hi_ms * 1e-3,
            work_us=spec.work_us, work_mult=r.work_mult,
            overhead_us=r.exec_syscall_overhead_us,
            exec_hiccup_p=r.exec_hiccup_p,
            exec_hiccup_lo_s=r.exec_hiccup_lo_ms * 1e-3,
            exec_hiccup_hi_s=r.exec_hiccup_hi_ms * 1e-3,
            offpath_mult=r.offpath_cpu_mult,
            stack_cpu_s=float(sum(tx) + sum(rx)),
        )

    # -- the invocation path (measured from the gateway, as in Fig 5) ------
    def invoke(self, fn_name: str, payload_scale: float = 1.0,
               fused: Tuple[str, ...] = ()) -> Generator:
        """Process: one warm invocation; returns the InvocationRecord.

        ``payload_scale`` scales the request payload (chain hops carry
        the upstream edge's transformed payload); ``fused`` names chain
        callees co-located in this sandbox — their function bodies run
        inline inside the exec span, skipping gateway and netstack."""
        spec = self.functions[fn_name]
        r = self.runtime
        rec = InvocationRecord(fn=fn_name, t_arrival=self.sim.now)
        p = spec.payload_bytes * payload_scale
        # 1. gateway: auth + route + proxy
        yield from self._app(r.gateway_us)
        # 2. gw -> provider (gRPC leg 1)
        yield from self.stack.deliver(p + 220)
        # 3. provider: resolve endpoint (+ proxy)
        yield from self._resolve(fn_name)
        yield from self._app(r.provider_us)
        # 4. provider -> function instance (gRPC leg 2)
        yield from self.stack.deliver(p + 180)
        # 5. in-instance watchdog dispatch
        yield from self._app(r.watchdog_us)
        # 6. function execution (+ fused chain callees, in-sandbox)
        rec.t_start_exec = self.sim.now
        yield from self._exec_function(spec)
        for nm in fused:
            yield from self._exec_function(self.functions[nm])
        rec.t_end_exec = self.sim.now
        # 7. response: fn -> provider -> gateway (reverse proxying)
        yield from self.stack.deliver(spec.response_bytes + 120)
        yield from self._app(r.provider_us * 0.35)
        yield from self.stack.deliver(spec.response_bytes + 120)
        yield from self._app(r.gateway_us * 0.35)
        rec.t_done = self.sim.now
        self.records.append(rec)
        return rec

    # -- metrics ----------------------------------------------------------
    def latencies_ms(self) -> List[float]:
        return [r.e2e * 1e3 for r in self.records]

    def exec_latencies_ms(self) -> List[float]:
        return [r.exec_latency * 1e3 for r in self.records]
