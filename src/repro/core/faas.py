"""faasd runtime model: gateway → provider → function instance (paper §2.1.1).

Every invocation traverses the gateway and the provider before reaching
the sandbox running the function (3 gRPC legs, responses flowing back the
same path).  Both orchestration services run either as containers on the
kernel stack (baseline) or inside Junction instances on the bypass stack
(junctiond mode, paper §3 — "Junction instances host not only the function
code but also the services in the FaaS runtime").

The provider optionally caches function metadata (replica count, IP,
port), keeping containerd/junctiond off the warm critical path (paper §4;
applied to BOTH backends for a fair comparison, as in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Generator, List, Optional, Union

from repro.core.containerd import Containerd
from repro.core.junction import JunctionInstance
from repro.core.latency import (AES_600B_WORK_US, JUNCTION_RUNTIME,
                                JUNCTION_STACK, KERNEL_RUNTIME, KERNEL_STACK,
                                RuntimeCosts)
from repro.core.netstack import NetStack
from repro.core.resources import CorePool
from repro.core.scheduler import JunctionScheduler, PollingModel
from repro.core.simulator import Simulator
from repro.core.junctiond import Junctiond


@dataclasses.dataclass
class FunctionSpec:
    """A deployable FaaS function."""
    name: str
    work_us: Union[float, Callable[[], float]] = AES_600B_WORK_US
    payload_bytes: int = 600
    response_bytes: int = 628          # input + AES-CTR overhead
    scale: int = 1
    max_cores: int = 2

    def work_seconds(self) -> float:
        w = self.work_us() if callable(self.work_us) else self.work_us
        return w * 1e-6


@dataclasses.dataclass
class InvocationRecord:
    fn: str
    t_arrival: float
    t_start_exec: float = 0.0
    t_end_exec: float = 0.0
    t_done: float = 0.0
    cold: bool = False

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def exec_latency(self) -> float:
        return self.t_end_exec - self.t_start_exec


class FaasdRuntime:
    """One worker node running the full faasd stack."""

    def __init__(self, sim: Simulator, *, backend: str = "junctiond",
                 n_cores: int = 10, provider_cache: bool = True,
                 polling_model: PollingModel = PollingModel.CENTRALIZED):
        self.sim = sim
        self.backend_name = backend
        self.provider_cache = provider_cache
        if backend == "junctiond":
            self.runtime: RuntimeCosts = JUNCTION_RUNTIME
            self.cores = CorePool(sim, n_cores, self.runtime)
            self.scheduler = JunctionScheduler(sim, self.cores, polling_model)
            self.scheduler.run()
            self.stack = NetStack(sim, JUNCTION_STACK, self.cores)
            self.manager = Junctiond(sim, self.scheduler)
            # the runtime services themselves live in Junction instances
            self._svc_gateway = JunctionInstance(sim, "svc/gateway", max_cores=4)
            self._svc_provider = JunctionInstance(sim, "svc/provider", max_cores=4)
            self._svc_gateway.ready = self._svc_provider.ready = True
            self.scheduler.register(self._svc_gateway)
            self.scheduler.register(self._svc_provider)
        elif backend == "containerd":
            self.runtime = KERNEL_RUNTIME
            self.cores = CorePool(sim, n_cores, self.runtime)
            self.scheduler = None
            self.stack = NetStack(sim, KERNEL_STACK, self.cores)
            self.manager = Containerd(sim)
        else:
            raise ValueError(f"unknown backend {backend!r}")
        self.functions: Dict[str, FunctionSpec] = {}
        self._cache: Dict[str, object] = {}
        self.records: List[InvocationRecord] = []
        self.cache_hits = 0
        self.cache_misses = 0
        self.rejected = 0

    # -- deployment -------------------------------------------------------
    def deploy(self, spec: FunctionSpec) -> Generator:
        self.functions[spec.name] = spec
        yield from self.manager.deploy(spec.name, scale=spec.scale,
                                       max_cores=spec.max_cores)
        if self.provider_cache:
            self._cache[spec.name] = self.manager.lookup(spec.name)

    def deploy_blocking(self, spec: FunctionSpec) -> None:
        p = self.sim.process(self.deploy(spec))
        p.completion.callbacks.append(lambda _v: self.sim.stop())
        self.sim.run()
        assert p.done

    # -- helpers ------------------------------------------------------
    def _app(self, base_us: float) -> Generator:
        """Application processing: critical-path CPU with jitter, plus
        off-critical-path CPU (GC/softirq/bookkeeping) consumed
        asynchronously — it caps throughput without adding latency at low
        load."""
        t = self.sim.lognormal_us(base_us, self.runtime.app_jitter_sigma)
        yield from self.cores.consume(t)
        extra = t * (self.runtime.offpath_cpu_mult - 1.0)
        if extra > 0:
            self.sim.process(self.cores.consume(extra))

    def _exec_function(self, spec: FunctionSpec) -> Generator:
        """The function body: compute + OS interactions (+ tail hiccups)."""
        r = self.runtime
        work = spec.work_seconds()
        overhead = self.sim.lognormal_us(r.exec_syscall_overhead_us,
                                         r.app_jitter_sigma)
        hic = 0.0
        if self.sim.rng.random() < r.exec_hiccup_p:
            hic = float(self.sim.rng.uniform(r.exec_hiccup_lo_ms,
                                             r.exec_hiccup_hi_ms)) * 1e-3
        yield from self.cores.consume(work + overhead)
        if hic:
            yield self.sim.timeout(hic)

    def _resolve(self, fn_name: str) -> Generator:
        """Provider resolving the function endpoint: cache or backend query."""
        if self.provider_cache and fn_name in self._cache:
            self.cache_hits += 1
            return self._cache[fn_name]
        self.cache_misses += 1
        rec = yield from self.manager.query(fn_name)
        if self.provider_cache:
            self._cache[fn_name] = rec
        return rec

    # -- the invocation path (measured from the gateway, as in Fig 5) ------
    def invoke(self, fn_name: str) -> Generator:
        """Process: one warm invocation; returns the InvocationRecord."""
        spec = self.functions[fn_name]
        r = self.runtime
        rec = InvocationRecord(fn=fn_name, t_arrival=self.sim.now)
        # 1. gateway: auth + route + proxy
        yield from self._app(r.gateway_us)
        # 2. gw -> provider (gRPC leg 1)
        yield from self.stack.deliver(spec.payload_bytes + 220)
        # 3. provider: resolve endpoint (+ proxy)
        yield from self._resolve(fn_name)
        yield from self._app(r.provider_us)
        # 4. provider -> function instance (gRPC leg 2)
        yield from self.stack.deliver(spec.payload_bytes + 180)
        # 5. in-instance watchdog dispatch
        yield from self._app(r.watchdog_us)
        # 6. function execution
        rec.t_start_exec = self.sim.now
        yield from self._exec_function(spec)
        rec.t_end_exec = self.sim.now
        # 7. response: fn -> provider -> gateway (reverse proxying)
        yield from self.stack.deliver(spec.response_bytes + 120)
        yield from self._app(r.provider_us * 0.35)
        yield from self.stack.deliver(spec.response_bytes + 120)
        yield from self._app(r.gateway_us * 0.35)
        rec.t_done = self.sim.now
        self.records.append(rec)
        return rec

    # -- metrics ----------------------------------------------------------
    def latencies_ms(self) -> List[float]:
        return [r.e2e * 1e3 for r in self.records]

    def exec_latencies_ms(self) -> List[float]:
        return [r.exec_latency * 1e3 for r in self.records]
