"""faasd runtime model: gateway → provider → function instance (paper §2.1.1).

Every invocation traverses the gateway and the provider before reaching
the sandbox running the function (3 gRPC legs, responses flowing back the
same path).  Where the orchestration services run and which datapath a
message rides is entirely the :class:`~repro.core.backends.ExecutionBackend`'s
business: the runtime composes with whatever bundle the backend provides
(cost tables, core pool, optional scheduler, netstack, lifecycle) and has
no backend-specific branches.  Backends resolve by registry name or can
be passed as ready instances.

The provider optionally caches function metadata (replica count, IP,
port), keeping the backend's control plane off the warm critical path
(paper §4; applied to EVERY backend for a fair comparison, as in the
paper).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Generator, List, Optional, Union

from repro.core.backends import ExecutionBackend, resolve_backend
from repro.core.latency import AES_600B_WORK_US, RuntimeCosts
from repro.core.scheduler import PollingModel
from repro.core.simulator import Simulator


@dataclasses.dataclass
class FunctionSpec:
    """A deployable FaaS function."""
    name: str
    work_us: Union[float, Callable[[], float]] = AES_600B_WORK_US
    payload_bytes: int = 600
    response_bytes: int = 628          # input + AES-CTR overhead
    scale: int = 1
    max_cores: int = 2

    def work_seconds(self) -> float:
        w = self.work_us() if callable(self.work_us) else self.work_us
        return w * 1e-6


@dataclasses.dataclass
class InvocationRecord:
    fn: str
    t_arrival: float
    t_start_exec: float = 0.0
    t_end_exec: float = 0.0
    t_done: float = 0.0
    cold: bool = False

    @property
    def e2e(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def exec_latency(self) -> float:
        return self.t_end_exec - self.t_start_exec


class FaasdRuntime:
    """One worker node running the full faasd stack."""

    def __init__(self, sim: Simulator, *,
                 backend: Union[str, ExecutionBackend] = "junctiond",
                 n_cores: Optional[int] = None, provider_cache: bool = True,
                 polling_model: Optional[PollingModel] = None):
        self.sim = sim
        self.provider_cache = provider_cache
        self.backend = resolve_backend(backend, sim, n_cores=n_cores,
                                       polling_model=polling_model)
        self.backend_name = self.backend.name
        self.runtime: RuntimeCosts = self.backend.runtime
        self.cores = self.backend.cores
        self.scheduler = self.backend.scheduler
        self.stack = self.backend.stack
        self.manager = self.backend     # lifecycle ops go to the backend
        self.functions: Dict[str, FunctionSpec] = {}
        self._cache: Dict[str, object] = {}
        self.records: List[InvocationRecord] = []
        self.cache_hits = 0
        self.cache_misses = 0
        self.rejected = 0

    # -- deployment -------------------------------------------------------
    def deploy(self, spec: FunctionSpec) -> Generator:
        self.functions[spec.name] = spec
        yield from self.manager.deploy(spec.name, scale=spec.scale,
                                       max_cores=spec.max_cores)
        if self.provider_cache:
            self._cache[spec.name] = self.manager.lookup(spec.name)

    def deploy_blocking(self, spec: FunctionSpec) -> None:
        p = self.sim.process(self.deploy(spec))
        p.completion.callbacks.append(lambda _v: self.sim.stop())
        self.sim.run()
        assert p.done

    # -- helpers ------------------------------------------------------
    def _app(self, base_us: float) -> Generator:
        """Application processing: critical-path CPU with jitter, plus
        off-critical-path CPU (GC/softirq/bookkeeping) consumed
        asynchronously — it caps throughput without adding latency at low
        load."""
        t = self.sim.lognormal_us(base_us, self.runtime.app_jitter_sigma)
        yield from self.cores.consume(t)
        extra = t * (self.runtime.offpath_cpu_mult - 1.0)
        if extra > 0:
            self.sim.process(self.cores.consume(extra))

    def _exec_function(self, spec: FunctionSpec) -> Generator:
        """The function body: compute + OS interactions (+ tail hiccups)."""
        r = self.runtime
        work = spec.work_seconds() * r.work_mult
        overhead = self.sim.lognormal_us(r.exec_syscall_overhead_us,
                                         r.app_jitter_sigma)
        hic = 0.0
        if self.sim.rng.random() < r.exec_hiccup_p:
            hic = float(self.sim.rng.uniform(r.exec_hiccup_lo_ms,
                                             r.exec_hiccup_hi_ms)) * 1e-3
        yield from self.cores.consume(work + overhead)
        if hic:
            yield self.sim.timeout(hic)

    def _resolve(self, fn_name: str) -> Generator:
        """Provider resolving the function endpoint: cache or backend query."""
        if self.provider_cache and fn_name in self._cache:
            self.cache_hits += 1
            return self._cache[fn_name]
        self.cache_misses += 1
        rec = yield from self.manager.query(fn_name)
        if self.provider_cache:
            self._cache[fn_name] = rec
        return rec

    # -- the invocation path (measured from the gateway, as in Fig 5) ------
    def invoke(self, fn_name: str) -> Generator:
        """Process: one warm invocation; returns the InvocationRecord."""
        spec = self.functions[fn_name]
        r = self.runtime
        rec = InvocationRecord(fn=fn_name, t_arrival=self.sim.now)
        # 1. gateway: auth + route + proxy
        yield from self._app(r.gateway_us)
        # 2. gw -> provider (gRPC leg 1)
        yield from self.stack.deliver(spec.payload_bytes + 220)
        # 3. provider: resolve endpoint (+ proxy)
        yield from self._resolve(fn_name)
        yield from self._app(r.provider_us)
        # 4. provider -> function instance (gRPC leg 2)
        yield from self.stack.deliver(spec.payload_bytes + 180)
        # 5. in-instance watchdog dispatch
        yield from self._app(r.watchdog_us)
        # 6. function execution
        rec.t_start_exec = self.sim.now
        yield from self._exec_function(spec)
        rec.t_end_exec = self.sim.now
        # 7. response: fn -> provider -> gateway (reverse proxying)
        yield from self.stack.deliver(spec.response_bytes + 120)
        yield from self._app(r.provider_us * 0.35)
        yield from self.stack.deliver(spec.response_bytes + 120)
        yield from self._app(r.gateway_us * 0.35)
        rec.t_done = self.sim.now
        self.records.append(rec)
        return rec

    # -- metrics ----------------------------------------------------------
    def latencies_ms(self) -> List[float]:
        return [r.e2e * 1e3 for r in self.records]

    def exec_latencies_ms(self) -> List[float]:
        return [r.exec_latency * 1e3 for r in self.records]
