"""Junction abstractions: instances, uProcs, queues (paper §2.2.1).

A ``JunctionInstance`` is a host-kernel process running the Junction
libOS kernel.  Executables inside it are ``uProc``s sharing that kernel;
each instance owns dedicated NIC packet queue pairs plus an event queue
that signals packet arrival to the centralized scheduler.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional

from repro.core.latency import JUNCTION_INSTANCE_INIT_MS
from repro.core.simulator import Queue, Simulator

_ids = itertools.count()


@dataclasses.dataclass
class UProc:
    """User-level process-like abstraction inside an instance."""
    name: str
    handler: Optional[Callable] = None
    threads_active: int = 0


class JunctionInstance:
    """One libOS process: packet queues + event queue + uProcs.

    Syscalls of interposed binaries are served by the Junction kernel in
    user space (no host trap); only core/memory multiplexing reaches the
    host kernel.
    """

    INIT_SECONDS = JUNCTION_INSTANCE_INIT_MS * 1e-3

    def __init__(self, sim: Simulator, name: str, max_cores: int = 2,
                 nic_queue_pairs: int = 1):
        self.sim = sim
        self.id = next(_ids)
        self.name = name
        self.max_cores = max_cores
        self.nic_queue_pairs = max(1, nic_queue_pairs)
        self.packet_queue: Queue = sim.queue()   # direct HW delivery
        self.event_queue: Queue = sim.queue()    # arrival signals -> scheduler
        self.uprocs: list[UProc] = []
        self.cores_granted = 0
        self.runnable_uthreads = 0
        self.ready = False

    def spawn_uproc(self, name: str, handler: Optional[Callable] = None) -> UProc:
        up = UProc(name=name, handler=handler)
        self.uprocs.append(up)
        return up

    @property
    def core_demand(self) -> int:
        """Cores the instance could use right now (runnable work + packets),
        bounded by its configured limit."""
        want = self.runnable_uthreads + len(self.packet_queue.items)
        return min(self.max_cores, want)

    def signal_packet(self) -> None:
        """HW writes the event queue; the scheduler polls it."""
        self.event_queue.put(self.sim.now)
