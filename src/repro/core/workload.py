"""Workload generators + metric helpers for the evaluation (paper §5).

Beyond the paper's two methodologies (sequential closed loop, Poisson open
loop) this module provides the arrival-process zoo the scenario suite
drives: bursty MMPP traffic (FaaSNet's dominant provisioning regime),
diurnal rate drift, trace replay, and heavy-tailed per-invocation work —
all deterministic under a fixed RNG so every stream is reproducible.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import warnings
from typing import (Callable, Dict, List, Mapping, Optional, Protocol,
                    Sequence, Tuple)

import numpy as np

from repro.core.faas import (FaasdRuntime, FunctionSpec, InvocationPlan,
                             InvocationRecord)
from repro.core.simulator import EventLoop, Simulator


def percentile(xs: Sequence[float], p: float) -> float:
    if len(xs) == 0:
        return float("nan")
    return float(np.percentile(np.asarray(xs), p))


@dataclasses.dataclass
class LatencySummary:
    n: int
    median_ms: float
    p99_ms: float
    mean_ms: float
    p999_ms: float

    @staticmethod
    def of(latencies_ms: Sequence[float]) -> "LatencySummary":
        # one array conversion + one percentile call for all three
        # quantiles: the knee search summarises every probe, so a
        # per-quantile sort compounds with the driver's cost
        a = np.asarray(latencies_ms, dtype=np.float64)
        if a.size == 0:
            nan = float("nan")
            return LatencySummary(0, nan, nan, nan, nan)
        med, p99, p999 = np.percentile(a, (50.0, 99.0, 99.9))
        return LatencySummary(n=int(a.size), median_ms=float(med),
                              p99_ms=float(p99), mean_ms=float(a.mean()),
                              p999_ms=float(p999))


def run_sequential(runtime: FaasdRuntime, fn_name: str, n: int = 100,
                   think_time_s: float = 0.0) -> LatencySummary:
    """Fig 5 methodology: n *sequential* invocations (closed loop)."""
    sim = runtime.sim

    def client():
        for _ in range(n):
            yield from runtime.invoke(fn_name)
            if think_time_s:
                yield sim.timeout(think_time_s)

    start = len(runtime.records)
    p = sim.process(client())
    p.completion.callbacks.append(lambda _v: sim.stop())
    sim.run()
    assert p.done, "sequential client did not finish"
    return LatencySummary.of([r.e2e * 1e3 for r in runtime.records[start:]])


def _completion_rps(done, t_start: float, t_min_end: float) -> float:
    """Completions per second of *busy* time (first window instant to the
    last completion): under overload this approximates the service
    capacity no matter how the observation window truncates the backlog,
    where the drain-inclusive achieved rate over-counts (everything
    eventually completes) and the loaded-window rate under-counts (the
    queue delays every completion past the window).  The knee search's
    bracketing signal."""
    if not done:
        return 0.0
    span = max(1e-9, max(max(r.t_done for r in done), t_min_end) - t_start)
    return len(done) / span


def run_open_loop(runtime: FaasdRuntime, fn_name: str, rate_rps: float,
                  duration_s: float = 2.0, warmup_s: float = 0.3,
                  max_outstanding: int = 20000,
                  on_arrival: Optional[Callable[[str], None]] = None,
                  on_done: Optional[Callable[[str], None]] = None,
                  ) -> Dict[str, float]:
    """Deprecated shim: Poisson open loop over a single function.

    Superseded by :func:`drive` with ``LoadSpec.single(fn, rate)``; this
    signature delegates there (one release of grace for out-of-tree
    callers) and will be removed."""
    warnings.warn(
        "run_open_loop is deprecated; use "
        "drive(runtime, LoadSpec.single(fn, rate), observer=...)",
        DeprecationWarning, stacklevel=2)
    load = LoadSpec(arrivals=PoissonArrivals(rate_rps), functions=(fn_name,),
                    duration_s=duration_s, warmup_s=warmup_s,
                    max_outstanding=max_outstanding, drain_s=2.0)
    res = drive(runtime, load, observer=_hooks_observer(on_arrival, on_done))
    res["offered_rps"] = rate_rps        # the legacy key meant the nominal rate
    return res


# ---------------------------------------------------------------------------
# Arrival processes.
#
# Each process turns an RNG into a sorted array of absolute arrival times in
# [0, duration_s).  Times are materialised up front (not sampled inside sim
# processes) so a stream is a pure function of (process params, rng state):
# fixed seed -> identical stream, which the determinism tests pin down.


class ArrivalProcess:
    """Base: a recipe for an arrival-time stream."""

    def times(self, rng: np.random.Generator, duration_s: float) -> np.ndarray:
        raise NotImplementedError

    def mean_rps(self) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless open-loop arrivals (the paper's Fig 6 methodology)."""
    rate_rps: float

    def times(self, rng, duration_s):
        if self.rate_rps <= 0 or duration_s <= 0:
            return np.empty(0)
        # draw in blocks: cheaper than a python loop at 10k+ rps
        out: List[np.ndarray] = []
        t, expect = 0.0, max(16, int(self.rate_rps * duration_s * 1.2))
        while t < duration_s:
            gaps = rng.exponential(1.0 / self.rate_rps, size=expect)
            ts = t + np.cumsum(gaps)
            out.append(ts)
            t = float(ts[-1])
        all_ts = np.concatenate(out)
        return all_ts[all_ts < duration_s]

    def mean_rps(self):
        return self.rate_rps


@dataclasses.dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """Two-state Markov-modulated Poisson process: quiet periods at
    ``base_rps`` punctuated by bursts at ``burst_rps`` (FaaSNet-style
    bursty multi-function provisioning traffic)."""
    base_rps: float
    burst_rps: float
    mean_quiet_s: float = 0.20
    mean_burst_s: float = 0.05
    start_in_burst: bool = False

    def times(self, rng, duration_s):
        out: List[float] = []
        t, burst = 0.0, self.start_in_burst
        seg_end = float(rng.exponential(
            self.mean_burst_s if burst else self.mean_quiet_s))
        while t < duration_s:
            rate = self.burst_rps if burst else self.base_rps
            gap = float(rng.exponential(1.0 / rate)) if rate > 0 else math.inf
            if t + gap < seg_end:
                t += gap
                if t < duration_s:
                    out.append(t)
            else:
                # exponential dwell is memoryless: restarting the gap at the
                # segment boundary keeps each segment piecewise-Poisson
                t = seg_end
                burst = not burst
                seg_end = t + float(rng.exponential(
                    self.mean_burst_s if burst else self.mean_quiet_s))
        return np.asarray(out)

    def mean_rps(self):
        tot = self.mean_quiet_s + self.mean_burst_s
        return (self.base_rps * self.mean_quiet_s
                + self.burst_rps * self.mean_burst_s) / tot


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidally-modulated Poisson (diurnal load drift compressed to
    sim time), sampled by Lewis-Shedler thinning against the peak rate."""
    mean_rate_rps: float
    amplitude: float = 0.8          # fraction of the mean, in [0, 1]
    period_s: float = 1.0
    phase: float = -math.pi / 2     # start at the trough

    def rate_at(self, t: float) -> float:
        return self.mean_rate_rps * (1.0 + self.amplitude
                                     * math.sin(2 * math.pi * t / self.period_s
                                                + self.phase))

    def times(self, rng, duration_s):
        peak = self.mean_rate_rps * (1.0 + self.amplitude)
        if peak <= 0 or duration_s <= 0:
            return np.empty(0)
        out: List[float] = []
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / peak))
            if t >= duration_s:
                break
            if rng.random() * peak < self.rate_at(t):
                out.append(t)
        return np.asarray(out)

    def mean_rps(self):
        return self.mean_rate_rps   # the sinusoid integrates to zero


@dataclasses.dataclass(frozen=True)
class TraceReplay(ArrivalProcess):
    """Replays a recorded (or synthesised) timestamp trace, optionally
    time-compressed; arrivals beyond duration_s are dropped."""
    trace_s: Sequence[float]
    time_scale: float = 1.0

    def times(self, rng, duration_s):
        ts = np.sort(np.asarray(self.trace_s, dtype=np.float64)) * self.time_scale
        return ts[(ts >= 0) & (ts < duration_s)]

    def mean_rps(self):
        ts = np.asarray(self.trace_s, dtype=np.float64) * self.time_scale
        span = float(ts.max() - ts.min()) if len(ts) > 1 else 1.0
        return len(ts) / max(span, 1e-9)


class _ParetoWork:
    """Truncated-Pareto work sampler; callable per invocation (the
    generator path draws one variate per request) and batchable via
    :meth:`sample` (the event-heap driver draws whole runs at once)."""

    __slots__ = ("rng", "xm", "alpha", "cap")

    def __init__(self, rng: np.random.Generator, xm: float, alpha: float,
                 cap: float):
        self.rng = rng
        self.xm = xm
        self.alpha = alpha
        self.cap = cap

    def __call__(self) -> float:
        u = 1.0 - self.rng.random()     # u in (0, 1]
        return float(min(self.xm * u ** (-1.0 / self.alpha), self.cap))

    def sample(self, n: int) -> np.ndarray:
        u = 1.0 - self.rng.random(n)
        return np.minimum(self.xm * u ** (-1.0 / self.alpha), self.cap)


def heavy_tailed_work(rng: np.random.Generator, median_us: float,
                      alpha: float = 1.6,
                      cap_mult: float = 200.0) -> Callable[[], float]:
    """Pareto per-invocation CPU work (heavy-tailed payload sizes): returns
    a sampler usable as ``FunctionSpec.work_us``.  ``median_us`` pins the
    distribution median; ``cap_mult`` truncates the tail so a single
    invocation cannot exceed median*cap_mult.  The sampler also exposes
    ``.sample(n)`` so batch drivers draw a run's worth of work at once."""
    xm = median_us / (2.0 ** (1.0 / alpha))
    return _ParetoWork(rng, xm, alpha, median_us * cap_mult)


# ---------------------------------------------------------------------------
# The open-loop driver: drive(runtime, LoadSpec, observer).
#
# One entry point subsumes the old run_open_loop / run_mixed_open_loop
# pair: a LoadSpec names the arrival process and function mix, a
# SimObserver taps per-request admission/completion (autoscalers, knee
# feedback, tracers), and the engine choice picks between the event-heap
# fast path (default; ~5 station holds + 1 off-path job per request on
# flat callbacks) and the generator reference path that walks the full
# 14-segment invocation chain.  Both produce the same result schema from
# the same record stream, so they are same-seed comparable.


class SimObserver(Protocol):
    """Per-request taps on an open-loop run.  Both fire only for
    *admitted* requests (rejected arrivals reach neither); ``on_done``
    fires at response completion, in completion order."""

    def on_arrival(self, fn_name: str) -> None: ...

    def on_done(self, fn_name: str) -> None: ...


class NullObserver:
    """Default observer; ``drive`` recognises it and skips dispatch
    entirely, so unobserved runs pay nothing on the hot path."""

    __slots__ = ()

    def on_arrival(self, fn_name: str) -> None:
        pass

    def on_done(self, fn_name: str) -> None:
        pass


_NULL_OBSERVER = NullObserver()


class _HookObserver:
    """Adapts the legacy ``on_arrival=``/``on_done=`` callback pair."""

    __slots__ = ("_on_arrival", "_on_done")

    def __init__(self, on_arrival, on_done):
        self._on_arrival = on_arrival
        self._on_done = on_done

    def on_arrival(self, fn_name: str) -> None:
        if self._on_arrival is not None:
            self._on_arrival(fn_name)

    def on_done(self, fn_name: str) -> None:
        if self._on_done is not None:
            self._on_done(fn_name)


def _hooks_observer(on_arrival, on_done) -> Optional[SimObserver]:
    if on_arrival is None and on_done is None:
        return None
    return _HookObserver(on_arrival, on_done)


def _check_chain_acyclic(chains: Mapping[str, Tuple["ChainEdge", ...]]):
    """Reject cyclic chain graphs at LoadSpec construction: a cycle
    would expand an arrival into an unbounded hop tree."""
    WHITE, GREY, BLACK = 0, 1, 2
    color: Dict[str, int] = {}

    def visit(fn: str, path: Tuple[str, ...]):
        color[fn] = GREY
        for e in chains.get(fn, ()):
            c = color.get(e.target, WHITE)
            if c == GREY:
                raise ValueError(
                    f"chain cycle: {' -> '.join(path + (e.target,))}")
            if c == WHITE:
                visit(e.target, path + (e.target,))
        color[fn] = BLACK

    for fn in chains:
        if color.get(fn, WHITE) == WHITE:
            visit(fn, (fn,))


@dataclasses.dataclass(frozen=True)
class ChainEdge:
    """One downstream edge of a function chain/DAG: on completion of the
    caller, ``target`` is invoked with probability ``prob``, its request
    payload scaled by ``payload_scale`` (the caller's transform of the
    data it forwards).  Scales compose multiplicatively along a chain."""

    target: str
    prob: float = 1.0
    payload_scale: float = 1.0

    def __post_init__(self):
        if not self.target:
            raise ValueError("ChainEdge needs a target function name")
        if not 0.0 < self.prob <= 1.0:
            raise ValueError(f"ChainEdge prob must be in (0, 1], "
                             f"got {self.prob}")
        if self.payload_scale <= 0.0:
            raise ValueError(f"ChainEdge payload_scale must be positive, "
                             f"got {self.payload_scale}")


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    """Platform-side fusion pass (Provuse-style): ``edges`` names the
    (caller, callee) chain edges to co-locate in the caller's sandbox —
    a fused hop skips the gateway and netstack entirely and runs as an
    appended exec inside the caller's request.  ``backends`` restricts
    the pass to the named backends (``None`` fuses everywhere), so one
    scenario can fuse on containerd-class backends while leaving a
    kernel-bypass backend unfused for comparison."""

    edges: Tuple[Tuple[str, str], ...] = ()
    backends: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        norm = []
        for e in self.edges:
            caller, callee = e
            if not caller or not callee:
                raise ValueError(f"FusionPlan edge needs non-empty caller "
                                 f"and callee, got {e!r}")
            pair = (str(caller), str(callee))
            if pair not in norm:
                norm.append(pair)
        object.__setattr__(self, "edges", tuple(norm))
        if self.backends is not None:
            object.__setattr__(self, "backends",
                               tuple(str(b) for b in self.backends))
        object.__setattr__(self, "_edge_set", frozenset(self.edges))

    def fuses(self, caller: str, callee: str) -> bool:
        return (caller, callee) in self._edge_set

    def applies_to(self, backend: str) -> bool:
        return self.backends is None or backend in self.backends


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """What to offer a runtime: an arrival process over a weighted
    function mix, plus the observation window.

    ``warmup_s`` (absolute) overrides ``warmup_frac`` when set — latency
    statistics and the completed-fraction denominator only count
    requests arriving after the warmup boundary, though every admitted
    request still runs (and reaches the observer).

    ``chains`` maps a function name to its downstream
    :class:`ChainEdge`\\ s: each admitted arrival of that function
    expands into its chain of hops, every non-fused hop re-entering
    admission as a request of its own.  ``fusion`` optionally co-locates
    selected edges (see :class:`FusionPlan`); it requires ``chains``."""

    arrivals: ArrivalProcess
    functions: Tuple[str, ...]
    weights: Optional[Tuple[float, ...]] = None
    duration_s: float = 2.0
    warmup_frac: float = 0.2
    warmup_s: Optional[float] = None
    max_outstanding: int = 20000
    drain_s: float = 2.0
    chains: Optional[Mapping[str, Tuple[ChainEdge, ...]]] = None
    fusion: Optional[FusionPlan] = None

    def __post_init__(self):
        object.__setattr__(self, "functions", tuple(self.functions))
        if not self.functions:
            raise ValueError("LoadSpec needs at least one function")
        if self.weights is not None:
            w = tuple(float(x) for x in self.weights)
            if len(w) != len(self.functions):
                raise ValueError(
                    f"{len(w)} weights for {len(self.functions)} functions")
            if any(x < 0.0 for x in w):
                raise ValueError(f"LoadSpec weights must be non-negative, "
                                 f"got {w}")
            if sum(w) <= 0.0:
                raise ValueError("LoadSpec weights must have a positive sum "
                                 "(all-zero weights cannot be normalized)")
            object.__setattr__(self, "weights", w)
        if self.duration_s <= 0.0:
            raise ValueError(f"duration_s must be positive, "
                             f"got {self.duration_s}")
        if not 0.0 <= self.warmup_frac < 1.0:
            raise ValueError(
                f"warmup_frac must be in [0, 1) — a warmup covering the "
                f"whole run leaves an empty observation window; "
                f"got {self.warmup_frac}")
        if self.warmup_s is not None and not \
                0.0 <= self.warmup_s < self.duration_s:
            raise ValueError(
                f"warmup_s must be in [0, duration_s) — warmup "
                f"{self.warmup_s}s leaves no observation window in a "
                f"{self.duration_s}s run")
        if self.chains is not None:
            chains = {str(k): tuple(v) for k, v in dict(self.chains).items()}
            for fn, edges in chains.items():
                for e in edges:
                    if not isinstance(e, ChainEdge):
                        raise ValueError(f"chains[{fn!r}] must hold "
                                         f"ChainEdge instances, got {e!r}")
            _check_chain_acyclic(chains)
            object.__setattr__(self, "chains", chains)
        if self.fusion is not None and self.chains is None:
            raise ValueError("LoadSpec fusion requires chains")

    @classmethod
    def single(cls, fn_name: str, rate_rps: float, **kw) -> "LoadSpec":
        """Poisson arrivals over one function (the Fig 6 shape)."""
        return cls(arrivals=PoissonArrivals(rate_rps), functions=(fn_name,),
                   **kw)

    @property
    def effective_warmup_s(self) -> float:
        return (self.warmup_s if self.warmup_s is not None
                else self.warmup_frac * self.duration_s)

    def normalized_weights(self) -> np.ndarray:
        if self.weights is None:
            k = len(self.functions)
            return np.full(k, 1.0 / k)
        w = np.asarray(self.weights, dtype=np.float64)
        return w / w.sum()


def _load_function_names(load: LoadSpec) -> Tuple[str, ...]:
    """Every function a load can invoke: the mix itself plus any chain
    targets reachable through its edges."""
    names = list(load.functions)
    seen = set(names)
    if load.chains:
        for fn, edges in load.chains.items():
            for nm in (fn,) + tuple(e.target for e in edges):
                if nm not in seen:
                    seen.add(nm)
                    names.append(nm)
    return tuple(names)


def _fast_capable(runtime: FaasdRuntime, load: LoadSpec) -> bool:
    """The event engine compiles the warm cached-resolve chain; a run
    that would take the provider's backend-query path (cache disabled or
    not yet populated) must use the generator engine, which models it."""
    if not getattr(runtime, "provider_cache", False):
        return False
    cache = getattr(runtime, "_cache", None)
    return cache is not None and all(fn in cache
                                     for fn in _load_function_names(load))


class _ChainTable:
    """Expanded request table for one chained run: rows 0..n_roots-1 are
    the admitted arrival stream's roots (in arrival order); hop rows are
    appended in DFS order.  ``children[i]`` lists the rows spawned when
    row ``i`` completes; ``members[i]`` lists the ``(fn_index,
    payload_scale)`` of chain callees fused *into* row ``i``'s sandbox
    (they add exec cost to the row instead of becoming rows)."""

    __slots__ = ("fn_names", "fidx", "scale", "depth", "root", "children",
                 "members", "n_roots")

    def __init__(self, fn_names, fidx, scale, depth, root, children,
                 members, n_roots):
        self.fn_names = fn_names
        self.fidx = fidx
        self.scale = scale
        self.depth = depth
        self.root = root
        self.children = children
        self.members = members
        self.n_roots = n_roots

    def fused_names(self, row: int) -> Tuple[str, ...]:
        return tuple(self.fn_names[f] for f, _s in self.members[row])


def _expand_chains(load: LoadSpec, picks, rng,
                   backend: str) -> Optional[_ChainTable]:
    """Expand the root arrival stream into its chain-hop request table.

    Returns ``None`` (consuming no rng state) when the load has no
    chains.  Trigger draws — one ``rng.random()`` per sub-unit-prob edge,
    in DFS order — are independent of the fusion plan, so a fused and an
    unfused run of the same seed expand the identical hop tree and stay
    row-for-row comparable."""
    chains = load.chains
    if not chains:
        return None
    fusion = load.fusion
    fuse = fusion is not None and fusion.applies_to(backend)
    names: List[str] = list(load.functions)
    index = {nm: i for i, nm in enumerate(names)}

    def fidx_of(nm: str) -> int:
        i = index.get(nm)
        if i is None:
            index[nm] = i = len(names)
            names.append(nm)
        return i

    picksL = picks.tolist() if hasattr(picks, "tolist") else list(picks)
    n = len(picksL)
    fidx = [int(p) for p in picksL]
    scale = [1.0] * n
    depth = [0] * n
    root = list(range(n))
    children: List[List[int]] = [[] for _ in range(n)]
    members: List[List[Tuple[int, float]]] = [[] for _ in range(n)]
    rand = rng.random

    def walk(host: int, fn: str, sc: float, dp: int, r: int):
        for e in chains.get(fn, ()):
            if e.prob < 1.0 and rand() >= e.prob:
                continue
            cs = sc * e.payload_scale
            if fuse and fusion.fuses(fn, e.target):
                members[host].append((fidx_of(e.target), cs))
                walk(host, e.target, cs, dp + 1, r)
            else:
                c = len(fidx)
                fidx.append(fidx_of(e.target))
                scale.append(cs)
                depth.append(dp + 1)
                root.append(r)
                children.append([])
                members.append([])
                children[host].append(c)
                walk(c, e.target, cs, dp + 1, r)

    for i in range(n):
        walk(i, load.functions[fidx[i]], 1.0, 0, i)
    return _ChainTable(tuple(names), fidx, scale, depth, root, children,
                       members, n)


def _chain_result(table: _ChainTable, AT, done_t, EX, t_warm: float,
                  rejected_hops: int) -> Dict[str, object]:
    """Per-chain/per-hop breakdown (artifact schema v6 ``chain`` block).

    Root end-to-end latency spans the root's arrival to the last
    completion in its subtree; only past-warmup roots whose *entire*
    expanded subtree completed count.  ``hops`` rows break latency and
    the per-hop platform tax (latency minus the exec span — gateway +
    netstack + queueing) down by hop depth; hop 0 is the root itself."""
    dt = np.asarray(done_t)
    root_ids = np.asarray(table.root)
    depth = np.asarray(table.depth)
    nr = table.n_roots
    comp = dt > 0.0
    exp_cnt = np.bincount(root_ids, minlength=nr)
    comp_cnt = np.bincount(root_ids[comp], minlength=nr)
    maxd = np.zeros(nr)
    if comp.any():
        np.maximum.at(maxd, root_ids[comp], dt[comp])
    root_at = np.asarray(AT)[:nr]
    full = (comp_cnt == exp_cnt) & comp[:nr] & (root_at >= t_warm)
    root_lat = (maxd[full] - root_at[full]) * 1e3
    s = LatencySummary.of(root_lat)
    warm_row = comp & (np.asarray(AT) >= t_warm)
    hops = []
    tax_wsum = 0.0
    tax_n = 0
    for d in range(int(depth.max()) + 1 if len(depth) else 1):
        m = warm_row & (depth == d)
        nd = int(np.count_nonzero(m))
        if nd == 0:
            continue
        hop_lat = (dt[m] - np.asarray(AT)[m]) * 1e3
        hs = LatencySummary.of(hop_lat)
        tax = float(np.mean(hop_lat - EX[m] * 1e3))
        tax_wsum += tax * nd
        tax_n += nd
        hops.append({"hop": d, "n": nd,
                     "median_ms": round(hs.median_ms, 6),
                     "p99_ms": round(hs.p99_ms, 6),
                     "mean_ms": round(hs.mean_ms, 6),
                     "tax_mean_ms": round(tax, 6)})
    return {
        "n_roots": int(nr),
        "roots_completed": int(np.count_nonzero(full)),
        "root_median_ms": s.median_ms,
        "root_p99_ms": s.p99_ms,
        "root_mean_ms": s.mean_ms,
        "hops": hops,
        "hop_tax_mean_ms": (tax_wsum / tax_n) if tax_n else float("nan"),
        "fused_members": int(sum(len(m) for m in table.members)),
        "rejected_hops": int(rejected_hops),
    }


def drive(runtime: FaasdRuntime, load: LoadSpec,
          observer: Optional[SimObserver] = None,
          engine: str = "events") -> Dict[str, object]:
    """Run ``load`` against ``runtime`` as an open loop; returns the
    result row (rates, completed fraction, latency summary, per-function
    summaries, raw latencies).

    ``engine="events"`` (default) executes hop-compressed invocations on
    the flat event heap — order-of-magnitude faster, statistically
    equivalent; ``engine="process"`` walks the full generator chain (the
    reference semantics).  Runs that the fast engine cannot represent
    (uncached endpoint resolution) fall back to the process engine
    automatically."""
    if engine not in ("events", "process"):
        raise ValueError(f"unknown engine {engine!r}")
    for fn in _load_function_names(load):
        if fn not in runtime.functions:
            raise KeyError(f"function {fn!r} not deployed")
    obs = observer if observer is not None else _NULL_OBSERVER
    if getattr(runtime, "is_cluster", False):
        # a fleet Cluster quacks like a runtime but routes per-arrival
        # through its gateway; only the event engine drives fleets
        if engine != "events":
            raise ValueError("a Cluster only runs on the event engine")
        from repro.fleet.driver import drive_cluster
        return drive_cluster(runtime, load, obs)
    if engine == "events" and not _fast_capable(runtime, load):
        engine = "process"
    if engine == "events":
        return _drive_events(runtime, load, obs)
    return _drive_process(runtime, load, obs)


def _assemble(runtime: FaasdRuntime, start_idx: int,
              fn_names: Sequence[str], t0: float, duration_s: float,
              warmup_s: float, drain_s: float, admitted: int,
              rejected0: int, offered_rps: float) -> Dict[str, object]:
    """Result row shared by both engines, from the run's record slice."""
    recs = [r for r in runtime.records[start_idx:]
            if r.t_arrival >= t0 + warmup_s]
    done = [r for r in recs if r.t_done <= t0 + duration_s + drain_s]
    lat = [r.e2e * 1e3 for r in recs]
    summary = LatencySummary.of(lat)
    per_fn: Dict[str, LatencySummary] = {}
    for name in fn_names:
        fn_lat = [r.e2e * 1e3 for r in recs if r.fn == name]
        if fn_lat:
            per_fn[name] = LatencySummary.of(fn_lat)
    return {
        "offered_rps": offered_rps,
        "achieved_rps": len(done) / max(1e-9, duration_s - warmup_s),
        "completion_rps": _completion_rps(done, t0 + warmup_s,
                                          t0 + duration_s),
        "completed_frac": len(done) / max(1, admitted),
        "median_ms": summary.median_ms,
        "p99_ms": summary.p99_ms,
        "mean_ms": summary.mean_ms,
        "p999_ms": summary.p999_ms,
        "n": summary.n,
        "rejected": runtime.rejected - rejected0,
        "per_fn": per_fn,
        "latencies_ms": lat,
    }


def _drive_process(runtime: FaasdRuntime, load: LoadSpec,
                   obs: SimObserver) -> Dict[str, object]:
    """Reference engine: every request walks the full generator chain."""
    sim = runtime.sim
    fn_names = load.functions
    duration_s = load.duration_s
    warmup_s = load.effective_warmup_s
    t0 = sim.now
    rel_times = load.arrivals.times(sim.rng, duration_s)
    picks = sim.rng.choice(len(fn_names), size=len(rel_times),
                           p=load.normalized_weights())
    table = _expand_chains(load, picks, sim.rng, runtime.backend_name)
    outstanding = [0]
    admitted = [0]                  # admitted past-warmup arrivals: the
    # completed_frac denominator must count every admitted request, not
    # just the ones that finished (records only exist on completion)
    rejected0 = runtime.rejected    # report this run's delta, not the
    # runtime-lifetime counter: knee-search bracketing reuses one runtime
    # across rates, and a cumulative count would fail rejected==0 forever
    observed = obs is not _NULL_OBSERVER

    if table is None:
        def driver():
            for rel_t, pick in zip(rel_times, picks):
                yield sim.timeout(t0 + float(rel_t) - sim.now)
                if outstanding[0] >= load.max_outstanding:
                    runtime.rejected += 1
                    continue
                outstanding[0] += 1
                if rel_t >= warmup_s:
                    admitted[0] += 1
                if observed:
                    obs.on_arrival(fn_names[pick])

                def one(fn=fn_names[pick]):
                    yield from runtime.invoke(fn)
                    outstanding[0] -= 1
                    if observed:
                        obs.on_done(fn)

                sim.process(one())
    else:
        fn_names = table.fn_names
        t_warm = t0 + warmup_s
        n_rows = len(table.fidx)
        AT = [0.0] * n_rows         # per-row spawn time (chain block)
        done_t = [0.0] * n_rows
        EX = [0.0] * n_rows         # recorded exec span (tax = e2e - EX)
        hop_rejected = [0]

        def one(row):
            fn = fn_names[table.fidx[row]]
            rec = yield from runtime.invoke(
                fn, payload_scale=table.scale[row],
                fused=table.fused_names(row))
            done_t[row] = rec.t_done
            EX[row] = rec.exec_latency
            outstanding[0] -= 1
            if observed:
                obs.on_done(fn)
            # the completed hop triggers its downstream edges: each child
            # re-enters admission as a request of its own
            for c in table.children[row]:
                if outstanding[0] >= load.max_outstanding:
                    runtime.rejected += 1
                    hop_rejected[0] += 1
                    continue
                outstanding[0] += 1
                if sim.now >= t_warm:
                    admitted[0] += 1
                if observed:
                    obs.on_arrival(fn_names[table.fidx[c]])
                AT[c] = sim.now
                sim.process(one(c))

        def driver():
            for row, rel_t in enumerate(rel_times):
                yield sim.timeout(t0 + float(rel_t) - sim.now)
                if outstanding[0] >= load.max_outstanding:
                    runtime.rejected += 1
                    continue
                outstanding[0] += 1
                if rel_t >= warmup_s:
                    admitted[0] += 1
                if observed:
                    obs.on_arrival(fn_names[table.fidx[row]])
                AT[row] = sim.now
                sim.process(one(row))

    start_idx = len(runtime.records)
    sim.process(driver())
    sim.run(until=t0 + duration_s + load.drain_s)
    res = _assemble(runtime, start_idx, fn_names, t0, duration_s, warmup_s,
                    load.drain_s, admitted[0], rejected0,
                    len(rel_times) / max(duration_s, 1e-9))
    if table is not None:
        res["chain"] = _chain_result(table, AT, done_t, np.asarray(EX),
                                     t0 + warmup_s, hop_rejected[0])
    return res


# The event engine's kernel-bypass analog: when a routed pool is
# uncontended at admit time (free cores beyond a one-core reservation
# margin, no waiters), the request's whole 3-station + 2-gap timeline is
# *fused* into one precomputed completion event (plus one off-path core
# release), skipping the per-station machine entirely — the same idea as
# acquire_fast's reservation-across-the-gap, extended to the request.
# Contended admits fall back to the per-station machine, whose thrash
# dynamics are path-dependent.  Tests flip this off to pin fused ==
# unfused accounting on contention-free schedules.
FUSED_FAST_PATH = True

# Runtime sim-sanitizer hook (repro.analysis.sanitizer): when flipped on
# (REPRO_SIM_CHECK=1 or sanitizer.install()), the fused-admit branches
# below assert their preconditions via _fused_admit_check.  Same
# zero-overhead pattern as FUSED_FAST_PATH: drivers hoist the flag to a
# local once per run, so the disabled cost is one boolean read per run.
SIM_CHECK = False


def _fused_admit_check(pool, t, end_t, off_end_t=None):
    """Delegate to the sanitizer's fused-admit assertion (imported
    lazily: only ever called when SIM_CHECK is on)."""
    from repro.analysis.sanitizer import fused_admit_check
    fused_admit_check(pool, t, end_t, off_end_t)


def _sample_request_matrices(runtime_of, fn_names, picks, rng, n):
    """Vectorized per-request cost matrices for one run, sampled once per
    function (the batch is routed afterwards).  Returns
    ``(H, G, OFF, EX, stack_cpu, n_hic)`` where ``stack_cpu``/``n_hic``
    are per-function lists (netstack accounting is the caller's business:
    the single-runtime driver books one stack, the fleet driver books the
    routed worker's)."""
    H = np.empty((n, 3))            # station CPU holds
    G = np.empty((n, 2))            # inter-station latency gaps
    OFF = np.empty(n)               # merged off-path CPU job
    EX = np.empty(n)                # exec-span approximation for records
    stack_cpu = [0.0] * len(fn_names)
    n_hic = [0] * len(fn_names)
    for f, nm in enumerate(fn_names):
        mask = picks == f
        m = int(mask.sum())
        if m == 0:
            continue
        plan = runtime_of(nm).invocation_plan(nm)
        h, g, off, ex, hic = plan.sample(rng, m)
        H[mask] = h
        G[mask] = g
        OFF[mask] = off
        EX[mask] = ex
        stack_cpu[f] = plan.stack_cpu_s
        n_hic[f] = hic
    return H, G, OFF, EX, stack_cpu, n_hic


def _sample_chain_matrices(runtime_of, table: _ChainTable, rng):
    """Vectorized per-row cost matrices for a chained run.  Rows group
    by ``(function, payload_scale)`` — a hop's plan depends on its
    scaled payload — and fused members append their exec-only cost to
    the host row (exec-station CPU, tail hiccup on the egress gap).

    Returns ``(H, G, OFF, EX, SC, n_hic)``: the per-row matrices of
    :func:`_sample_request_matrices` plus ``SC``, the per-row netstack
    CPU (scale-dependent, so per-function constants no longer work),
    and per-function net-hiccup counts.  Fused members book no netstack
    cost at all — they never touch the stack."""
    fn_names = table.fn_names
    picks = np.asarray(table.fidx, dtype=np.intp)
    scales = np.asarray(table.scale, dtype=np.float64)
    N = int(picks.size)
    H = np.empty((N, 3))
    G = np.empty((N, 2))
    OFF = np.empty(N)
    EX = np.empty(N)
    SC = np.empty(N)
    n_hic = [0] * len(fn_names)
    for f, nm in enumerate(fn_names):
        fmask = picks == f
        if not fmask.any():
            continue
        for s in sorted(set(scales[fmask].tolist())):
            m2 = fmask & (scales == s)
            m = int(m2.sum())
            plan = runtime_of(nm).invocation_plan(nm, payload_scale=s)
            h, g, off, ex, hic = plan.sample(rng, m)
            H[m2] = h
            G[m2] = g
            OFF[m2] = off
            EX[m2] = ex
            SC[m2] = plan.stack_cpu_s
            n_hic[f] += hic
    by_f: Dict[int, List[int]] = {}
    for host, ms in enumerate(table.members):
        for fm, _s in ms:
            by_f.setdefault(fm, []).append(host)
    for fm in sorted(by_f):
        hosts = by_f[fm]
        nm = fn_names[fm]
        plan = runtime_of(nm).invocation_plan(nm)
        cpu, hic = plan.sample_exec(rng, len(hosts))
        for j, host in enumerate(hosts):
            H[host, 1] += cpu[j]
            G[host, 1] += hic[j]
            EX[host] += cpu[j] + hic[j]
    return H, G, OFF, EX, SC, n_hic


def _fused_arrays(AT, H, G, OFF, EX):
    """Precomputed absolute timelines for the fused fast path, as flat
    Python lists (structure-of-arrays: one ``.tolist()`` per column beats
    per-request tuple/list allocation by a wide margin).

    Returns ``(END, OFFEND, CPU, EXS, EXE)``: uncontended completion
    time, off-path job end, total CPU charged per request, and the
    recorded exec span's start/end — all identical to what the
    per-station machine produces on an uncontended walk (thrash 1.0,
    every gap reservation granted)."""
    h0 = H[:, 0]
    span = H.sum(axis=1) + G.sum(axis=1)
    exs = AT + h0 + G[:, 0]
    return ((AT + span).tolist(), (AT + h0 + OFF).tolist(),
            (H.sum(axis=1) + OFF).tolist(), exs.tolist(),
            (exs + EX).tolist())


def _append_records(records, fn_names, picksL, ATL, ex_start, EX, done_t):
    """Materialise :class:`InvocationRecord`\\ s for every completed
    request, in completion order, after the event loop has drained —
    the hot loop only writes ``done_t``/``ex_start`` floats."""
    dt = np.asarray(done_t)
    idx = np.flatnonzero(dt > 0.0)
    if not idx.size:
        return
    idx = idx[np.argsort(dt[idx], kind="stable")]
    ex_end = (np.asarray(ex_start) + EX).tolist()
    rec = InvocationRecord
    append = records.append
    for i in idx.tolist():
        append(rec(fn_names[picksL[i]], ATL[i], ex_start[i], ex_end[i],
                   done_t[i]))


def _events_result(fn_names, picks, AT, done_t, t0, duration_s, warmup_s,
                   drain_s, admitted, rejected, offered_rps):
    """Vectorized result row for the event engines (same schema as
    :func:`_assemble`, computed from the driver's flat arrays instead of
    per-record Python loops)."""
    dt = np.asarray(done_t)
    m = (dt > 0.0) & (AT >= t0 + warmup_s)      # completed, past warmup
    lat = (dt[m] - AT[m]) * 1e3
    dmask = m & (dt <= t0 + duration_s + drain_s)
    n_done = int(np.count_nonzero(dmask))
    summary = LatencySummary.of(lat)
    per_fn: Dict[str, LatencySummary] = {}
    pm = picks[m]
    for f, name in enumerate(fn_names):
        fn_lat = lat[pm == f]
        if fn_lat.size:
            per_fn[name] = LatencySummary.of(fn_lat)
    t_start = t0 + warmup_s
    if n_done:
        span = max(1e-9, max(float(dt[dmask].max()), t0 + duration_s)
                   - t_start)
        completion_rps = n_done / span
    else:
        completion_rps = 0.0
    return {
        "offered_rps": offered_rps,
        "achieved_rps": n_done / max(1e-9, duration_s - warmup_s),
        "completion_rps": completion_rps,
        "completed_frac": n_done / max(1, admitted),
        "median_ms": summary.median_ms,
        "p99_ms": summary.p99_ms,
        "mean_ms": summary.mean_ms,
        "p999_ms": summary.p999_ms,
        "n": summary.n,
        "rejected": rejected,
        "per_fn": per_fn,
        "latencies_ms": lat.tolist(),
    }


def _drive_events(runtime: FaasdRuntime, load: LoadSpec,
                  obs: SimObserver) -> Dict[str, object]:
    """Fast engine: hop-compressed invocations on the flat event heap.

    All per-request randomness is drawn up front in vectorized batches
    (arrival times from the process, then per function: app jitter, work,
    overhead, hiccups, net jitter — see ``InvocationPlan.sample``); the
    event loop then runs pure float arithmetic over plain callbacks.
    Generator processes already on the simulator (autoscaler operations,
    the Junction scheduler poll loop, provisioning storms) interleave
    through the shared heap and contend for the same core pool.

    Requests admitted into an uncontended pool take the *fused* path:
    the whole station timeline collapses to one precomputed completion
    event (see ``FUSED_FAST_PATH`` above) — ~1-2 heap events per request
    instead of ~4 — while contended admits walk the per-station machine
    below, unchanged."""
    sim = runtime.sim
    fn_names = load.functions
    duration_s = load.duration_s
    warmup_s = load.effective_warmup_s
    drain_s = load.drain_s
    max_out = load.max_outstanding
    t0 = sim.now
    rel = load.arrivals.times(sim.rng, duration_s)
    n = len(rel)
    if len(fn_names) > 1 or load.chains is not None:
        # chained runs always draw picks so the trigger-draw stream
        # that follows stays aligned with the process engine's
        picks = sim.rng.choice(len(fn_names), size=n,
                               p=load.normalized_weights())
    else:
        picks = np.zeros(n, dtype=np.intp)
    table = _expand_chains(load, picks, sim.rng, runtime.backend_name)

    AT = t0 + rel
    stack = runtime.stack
    if table is None:
        N = n
        H, G, OFF, EX, stack_cpu, n_hic = _sample_request_matrices(
            lambda _nm: runtime, fn_names, picks, sim.rng, n)
        for f in range(len(fn_names)):
            m = int((picks == f).sum()) if len(fn_names) > 1 else n
            # netstack accounting the per-request path would have done
            stack.messages += 4 * m
            stack.cpu_spent += m * stack_cpu[f]
            stack.hiccups += n_hic[f]
    else:
        fn_names = table.fn_names
        picks = np.asarray(table.fidx, dtype=np.intp)
        N = int(picks.size)
        H, G, OFF, EX, SC, n_hic = _sample_chain_matrices(
            lambda _nm: runtime, table, sim.rng)
        # every table row (root or hop) is one request on this stack;
        # fused members contribute nothing (SC covers rows only)
        stack.messages += 4 * N
        stack.cpu_spent += float(SC.sum())
        stack.hiccups += sum(n_hic)

    # flat structure-of-arrays buffers: one list per column (station
    # holds indexed 3*i+k, gaps 2*i+k) — Python float access without the
    # per-request inner lists the old H.tolist() materialised
    H3 = H.ravel().tolist()
    G2 = G.ravel().tolist()
    OFFL = OFF.tolist()
    picksL = picks.tolist()
    if table is None:
        ATL = AT.tolist()
        rootATL = ATL
        ENDL, OFFENDL, CPUL, EXSL, EXEL = _fused_arrays(AT, H, G, OFF, EX)
        # exec-span start: fused requests keep the precomputed
        # uncontended value; the station machine overwrites it with the
        # actual exec grant
        ex_start = list(EXSL)
    else:
        # a hop's arrival time is only known when its parent completes:
        # keep the fused timeline *relative* and let _enter stamp the
        # absolute values at spawn
        rootATL = AT.tolist()
        ATL = [0.0] * N
        SPANL = (H.sum(axis=1) + G.sum(axis=1)).tolist()
        OFFRELL = (H[:, 0] + OFF).tolist()
        H0G0L = (H[:, 0] + G[:, 0]).tolist()
        ENDL = [0.0] * N
        OFFENDL = [0.0] * N
        ex_start = [0.0] * N
    done_t = [0.0] * N              # completion time; 0.0 = not completed

    # The station machine below inlines CorePool.acquire_fast /
    # release_fast field-for-field (busy/_waiters/_queued_weight stay
    # consistent, and queued grants drain through pool._grant_next either
    # way) — each spared function call is a measurable slice of the wall
    # time.  busy_time/served/cache_hits/rejected are pure end-of-run
    # accounting (nothing reads them mid-run), so they accumulate in
    # locals and flush once after the loop.  Two consequences of the
    # pool's invariants are exploited: an immediate grant requires an
    # empty waiter queue, where backlog == 0 and the thrash multiplier
    # is exactly 1.0; only grants popped off the waiter queue (by
    # _granted/_off_granted below) see a non-trivial backlog.
    pool = runtime.cores
    waiters = pool._waiters
    grant_next = pool._grant_next
    off_pend = pool._off_pend
    materialize = pool._materialize
    hpush = heapq.heappush
    hpop = heapq.heappop
    t_coeff = runtime.runtime.thrash_coeff
    t_cap = runtime.runtime.thrash_cap
    heap = sim._heap
    push = heapq.heappush
    counter = sim._counter
    records = runtime.records
    off_weight = InvocationPlan.OFFPATH_BACKLOG_WEIGHT
    st_weight = InvocationPlan.STATION_BACKLOG_WEIGHT
    observed = obs is not _NULL_OBSERVER
    fuse = FUSED_FAST_PATH
    check = SIM_CHECK
    t_warm = t0 + warmup_s
    outstanding = 0
    busy_time = 0.0
    served = 0
    rejected = 0
    rejected_warm = 0
    entered = 0                     # chained runs: rows that arrived
    entered_warm = 0
    hop_rejected = 0
    CHILD = table.children if table is not None else None
    fused = bytearray(N)            # fused admits; accounted post-loop

    def _admit(i, t):
        # per-request totals that nothing reads mid-run (cache_hits,
        # post-warmup admits, fused busy_time/served) are derived after
        # the loop from the arrival count and the fused bitmap — the
        # admit path only touches admission state
        nonlocal outstanding, rejected, rejected_warm
        if outstanding >= max_out:
            rejected += 1
            if t >= t_warm:
                rejected_warm += 1
            return
        outstanding += 1
        if observed:
            obs.on_arrival(fn_names[picksL[i]])
        while off_pend and off_pend[0] <= t:   # expired lazy releases
            hpop(off_pend)
            pool.busy -= 1
        b = pool.busy
        if not waiters:
            if fuse:
                # fused fast path: the whole timeline is precomputed
                # (thrash 1.0 throughout); holds the on-path core to
                # completion and the off-path core to the off job's end
                # (released lazily, no heap event), always leaving one
                # spare core unreserved
                off = OFFL[i]
                if off > 0.0:
                    if b + 2 < pool.n_cores:
                        if check:
                            _fused_admit_check(pool, t, ENDL[i],
                                               OFFENDL[i])
                        pool.busy = b + 2
                        fused[i] = 1
                        push(heap, (ENDL[i], next(counter),
                                    _fused_done, (i,)))
                        hpush(off_pend, OFFENDL[i])
                        return
                elif b + 1 < pool.n_cores:
                    if check:
                        _fused_admit_check(pool, t, ENDL[i])
                    pool.busy = b + 1
                    fused[i] = 1
                    push(heap, (ENDL[i], next(counter), _fused_done, (i,)))
                    return
            if b < pool.n_cores:
                pool.busy = b + 1
                eff = H3[3 * i]     # empty queue -> thrash == 1.0
                push(heap, (t + eff, next(counter), _complete,
                            (i, 0, eff, t)))
                return
        if off_pend:
            materialize()
        waiters.append((t, _granted, (i, 0), st_weight))
        pool._queued_weight += st_weight - 1

    def _fused_done(i):
        # one event for the whole request: release the on-path core and
        # finish (records and busy_time/served accounting are
        # materialised after the loop, off the hot path — done_t is the
        # only per-completion state)
        nonlocal outstanding
        pool.busy -= 1
        if waiters:
            grant_next()
        outstanding -= 1
        end = ENDL[i]
        done_t[i] = end
        if observed:
            obs.on_done(fn_names[picksL[i]])
        if CHILD is not None:
            for c in CHILD[i]:
                _enter(c, end)

    def _complete(i, k, eff, start):
        # release the station's core (event time is always start + eff)
        nonlocal busy_time, served
        pool.busy -= 1
        busy_time += eff
        served += 1
        if waiters:
            grant_next()
        now = start + eff
        if k == 2:
            nonlocal outstanding
            outstanding -= 1
            done_t[i] = now
            if observed:
                obs.on_done(fn_names[picksL[i]])
            if CHILD is not None:
                for c in CHILD[i]:
                    _enter(c, now)
            return
        while off_pend and off_pend[0] <= now:  # expired lazy releases
            hpop(off_pend)
            pool.busy -= 1
        if k == 0:
            off = OFFL[i]
            if off > 0.0:           # merged off-path CPU job
                b = pool.busy
                if b < pool.n_cores and not waiters:
                    pool.busy = b + 1
                    push(heap, (now + off, next(counter), _off_done, (off,)))
                else:
                    if off_pend:
                        materialize()
                    waiters.append((now, _off_granted, (off,), off_weight))
                    pool._queued_weight += off_weight - 1
        else:
            # completion of the exec station: its grant time starts the
            # recorded exec span
            ex_start[i] = start
        # acquire the next station's core, available after the net gap
        avail = now + G2[2 * i + k]
        k += 1
        b = pool.busy
        nc = pool.n_cores
        if b < nc and not waiters:
            if b < nc - 1:
                # reserve through the µs-scale gap while the pool keeps a
                # spare core; near saturation fall through to a wakeup
                # event at avail instead (no capacity is held idle)
                pool.busy = b + 1
                eff = H3[3 * i + k]
                push(heap, (avail + eff, next(counter), _complete,
                            (i, k, eff, avail)))
            else:
                push(heap, (avail, next(counter), _retry, (avail, i, k)))
        else:
            if off_pend:
                materialize()
            waiters.append((avail, _granted, (i, k), st_weight))
            pool._queued_weight += st_weight - 1

    def _retry(avail, i, k):
        while off_pend and off_pend[0] <= avail:  # expired lazy releases
            hpop(off_pend)
            pool.busy -= 1
        b = pool.busy
        if b < pool.n_cores and not waiters:
            pool.busy = b + 1
            eff = H3[3 * i + k]     # empty queue -> thrash == 1.0
            push(heap, (avail + eff, next(counter), _complete,
                        (i, k, eff, avail)))
        else:
            if off_pend:
                materialize()
            waiters.append((avail, _granted, (i, k), st_weight))
            pool._queued_weight += st_weight - 1

    def _granted(start, i, k):
        # popped off the waiter queue by a release; the remaining backlog
        # sets this hold's thrash multiplier (as in CorePool.consume)
        th = 1.0 + t_coeff * (len(waiters) + pool._queued_weight) \
            / pool.n_cores
        eff = H3[3 * i + k] * (t_cap if th > t_cap else th)
        push(heap, (start + eff, next(counter), _complete, (i, k, eff, start)))

    def _off_granted(start, off):
        th = 1.0 + t_coeff * (len(waiters) + pool._queued_weight) \
            / pool.n_cores
        eff = off * (t_cap if th > t_cap else th)
        push(heap, (start + eff, next(counter), _off_done, (eff,)))

    def _off_done(eff):
        nonlocal busy_time, served
        pool.busy -= 1
        busy_time += eff
        served += 1
        if waiters:
            grant_next()

    if table is not None:
        DEPTHL = table.depth
        SPANL_ = SPANL
        OFFRELL_ = OFFRELL
        H0G0L_ = H0G0L

        def _enter(i, t):
            # a root arrival or a spawned chain hop: stamp its absolute
            # fused timeline, then take the normal admission path
            nonlocal entered, entered_warm, hop_rejected
            ATL[i] = t
            ENDL[i] = t + SPANL_[i]
            OFFENDL[i] = t + OFFRELL_[i]
            ex_start[i] = t + H0G0L_[i]
            entered += 1
            if t >= t_warm:
                entered_warm += 1
            r0 = rejected
            _admit(i, t)
            if rejected > r0 and DEPTHL[i]:
                hop_rejected += 1

        delivered = EventLoop(sim).run(t0 + duration_s + drain_s,
                                       rootATL, _enter)
    else:
        _enter = None
        delivered = EventLoop(sim).run(t0 + duration_s + drain_s,
                                       ATL, _admit)
    # deferred per-request accounting: every delivered non-rejected
    # arrival is one warm cached resolve; a fused request whose single
    # completion event fired (done_t set — straddlers past the drain
    # horizon never fire, as their unfused stations would not have)
    # contributes its whole precomputed CPU/served total
    fmask = (np.frombuffer(fused, dtype=np.uint8).astype(bool)
             & (np.asarray(done_t) > 0.0))
    pool.busy_time += busy_time + float((H.sum(axis=1) + OFF)[fmask].sum())
    pool.served += served + int(3 * fmask.sum()
                                + np.count_nonzero(fmask & (OFF > 0.0)))
    if table is None:
        runtime.cache_hits += delivered - rejected
        admitted = (int(np.count_nonzero(AT[:delivered] >= t_warm))
                    - rejected_warm)
    else:
        # roots and hops alike: each admitted row did one warm resolve
        runtime.cache_hits += entered - rejected
        admitted = entered_warm - rejected_warm
        AT = np.asarray(ATL)
    runtime.rejected += rejected
    _append_records(records, fn_names, picksL, ATL, ex_start, EX, done_t)
    res = _events_result(fn_names, picks, AT, done_t, t0, duration_s,
                         warmup_s, drain_s, admitted, rejected,
                         n / max(duration_s, 1e-9))
    if table is not None:
        res["chain"] = _chain_result(table, AT, done_t, EX, t_warm,
                                     hop_rejected)
    return res


def run_mixed_open_loop(runtime: FaasdRuntime, fn_names: Sequence[str],
                        weights: Sequence[float], arrivals: ArrivalProcess,
                        duration_s: float, warmup_frac: float = 0.2,
                        max_outstanding: int = 20000,
                        drain_s: float = 2.0,
                        on_arrival: Optional[Callable[[str], None]] = None,
                        on_done: Optional[Callable[[str], None]] = None,
                        ) -> Dict[str, object]:
    """Deprecated shim: open-loop run over a weighted function mix.

    Superseded by :func:`drive` with a :class:`LoadSpec`; delegates there
    (one release of grace for out-of-tree callers) and will be removed."""
    warnings.warn(
        "run_mixed_open_loop is deprecated; use "
        "drive(runtime, LoadSpec(...), observer=...)",
        DeprecationWarning, stacklevel=2)
    load = LoadSpec(arrivals=arrivals, functions=tuple(fn_names),
                    weights=tuple(float(x) for x in weights),
                    duration_s=duration_s, warmup_frac=warmup_frac,
                    max_outstanding=max_outstanding, drain_s=drain_s)
    return drive(runtime, load, observer=_hooks_observer(on_arrival, on_done))


def _row_rate(row: Dict[str, float], rate_key: str) -> float:
    """A row's offered rate: the nominal grid/search rate when positive,
    else the measured offered rate (trace replay fixes the rate)."""
    return float(row.get(rate_key) or row["offered_rps"])


def _row_meets_slo(row: Dict[str, float], rate: float, slo_p99_ms: float,
                   min_achieved_frac: float) -> bool:
    p99 = float(row["p99_ms"])
    return (math.isfinite(p99) and p99 <= slo_p99_ms
            and row.get("rejected", 0) == 0
            and row["achieved_rps"] >= min_achieved_frac * rate)


def knee_index_of_curve(curve: List[Dict[str, float]], slo_p99_ms: float,
                        min_achieved_frac: float = 0.85,
                        rate_key: str = "nominal_rps") -> Optional[int]:
    """Index of the knee row (highest rate meeting the SLO criteria), or
    ``None`` when no row qualifies.  Callers wanting the knee's latency
    row should use this index instead of re-matching the returned rate by
    float equality — search-generated rates are not grid-aligned."""
    best_idx: Optional[int] = None
    best = 0.0
    for i, r in enumerate(curve):
        rate = _row_rate(r, rate_key)
        if _row_meets_slo(r, rate, slo_p99_ms, min_achieved_frac) \
                and rate >= best:
            best, best_idx = rate, i
    return best_idx


def knee_of_curve(curve: List[Dict[str, float]], slo_p99_ms: float,
                  min_achieved_frac: float = 0.85,
                  rate_key: str = "nominal_rps") -> float:
    """Max offered rate whose P99 meets the SLO with no rejects and
    achieved throughput within ``min_achieved_frac`` of offered.

    Rows without a positive nominal rate (e.g. trace replay, where the
    trace fixes the rate) fall back to the measured offered rate so the
    achieved-fraction check still binds."""
    idx = knee_index_of_curve(curve, slo_p99_ms, min_achieved_frac, rate_key)
    return 0.0 if idx is None else _row_rate(curve[idx], rate_key)


# ---------------------------------------------------------------------------
# Adaptive SLO-knee search.
#
# Fixed rate grids spend most of their samples on the flat part of the
# throughput-latency curve; the interesting behaviour lives in a narrow
# band at the capacity cliff (FaaSNet, Quark).  KneeSearch spends samples
# there instead: a coarse exponential bracketing pass finds a [pass, fail]
# rate bracket, then geometric bisection narrows it to a relative-width
# tolerance.  Failing probes feed back their *achieved* throughput as a
# capacity ceiling (an overloaded run completes work at roughly the
# service capacity, and the SLO knee cannot exceed it), which collapses
# the bracket in one probe even when the initial guess is far off — so a
# new backend needs zero hand-measured grid entries.


@dataclasses.dataclass
class KneeSearchResult:
    """Outcome of one :class:`KneeSearch` run.

    ``knee_rps`` is the highest probed rate that met the SLO criteria
    (0.0 when nothing was sustainable); ``[lo_rps, hi_rps]`` is the final
    bracket; ``trace`` records every probe in issue order (rate, phase,
    verdict, and the probe's measured row) — the artifact's audit trail
    for how the knee was located."""
    knee_rps: float
    lo_rps: float
    hi_rps: float
    n_probes: int
    converged: bool
    trace: List[Dict[str, object]]

    def knee_trace_index(self) -> Optional[int]:
        """Index (into ``trace``/``rows``) of the knee probe: the highest
        passing *full-resolution* probe — a passing low-res bracket probe
        under-samples the tail and never certifies the knee."""
        best_idx, best = None, 0.0
        for i, t in enumerate(self.trace):
            if (t["ok"] and t["phase"] == "bisect"
                    and float(t["rate_rps"]) >= best):
                best, best_idx = float(t["rate_rps"]), i
        return best_idx


class KneeSearch:
    """Adaptive SLO-knee locator over an open-loop probe function.

    ``probe(rate_rps, phase)`` runs one open-loop experiment at the given
    offered rate and returns its result row (needs ``p99_ms``,
    ``achieved_rps``, ``rejected``); ``phase`` is ``"bracket"`` or
    ``"bisect"`` so callers can run bracketing probes at lower resolution
    (shorter duration).  The search is deterministic given a
    deterministic probe.

    ``max_probes`` is a hard sample budget: the search never issues more
    open-loop runs than that, returning the best bracket found so far
    with ``converged=False`` when the budget ran out first.
    """

    def __init__(self, probe: Callable[[float, str], Dict[str, object]],
                 slo_p99_ms: float, rate0: float = 500.0,
                 growth: float = 2.0, shrink: float = 0.75,
                 rel_tol: float = 0.10, max_probes: int = 12,
                 min_achieved_frac: float = 0.85,
                 min_completed_frac: float = 0.95,
                 rate_floor: float = 25.0, rate_ceiling: float = 64000.0):
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        if not 0.0 < shrink < 1.0:
            raise ValueError(f"shrink must be in (0, 1), got {shrink}")
        if rel_tol <= 0.0:
            raise ValueError(f"rel_tol must be positive, got {rel_tol}")
        if max_probes < 1:
            raise ValueError(f"max_probes must be >= 1, got {max_probes}")
        if not 0.0 < rate_floor <= rate_ceiling:
            raise ValueError(f"need 0 < rate_floor <= rate_ceiling, got "
                             f"[{rate_floor}, {rate_ceiling}]")
        self.probe = probe
        self.slo_p99_ms = slo_p99_ms
        self.rate0 = rate0
        self.growth = growth
        self.shrink = shrink
        self.rel_tol = rel_tol
        self.max_probes = max_probes
        self.min_achieved_frac = min_achieved_frac
        self.min_completed_frac = min_completed_frac
        self.rate_floor = rate_floor
        self.rate_ceiling = rate_ceiling

    def _clamp(self, rate: float) -> float:
        return min(max(rate, self.rate_floor), self.rate_ceiling)

    def _ok(self, row: Dict[str, object], rate: float) -> bool:
        """Probe verdict.  Prefers the *completed fraction* (did the work
        admitted during the run finish within the drain window?) over the
        grid criterion's achieved-vs-nominal ratio: the latter compares a
        completion count against the nominal rate, so at short probe
        durations Poisson arrival-count noise alone can flip it."""
        frac = row.get("completed_frac")
        if frac is None:
            return _row_meets_slo(row, rate, self.slo_p99_ms,
                                  self.min_achieved_frac)
        p99 = float(row["p99_ms"])
        return (math.isfinite(p99) and p99 <= self.slo_p99_ms
                and row.get("rejected", 0) == 0
                and float(frac) >= self.min_completed_frac)

    def _probe(self, rate: float, phase: str,
               trace: List[Dict[str, object]]) -> bool:
        row = self.probe(rate, phase)
        ok = self._ok(row, rate)
        trace.append({"rate_rps": float(rate), "phase": phase, "ok": ok,
                      "p99_ms": float(row.get("p99_ms", float("nan"))),
                      "achieved_rps": float(row.get("achieved_rps", 0.0)),
                      "completion_rps": float(
                          row.get("completion_rps",
                                  row.get("achieved_rps", 0.0))),
                      "row": row})
        return ok

    def _descend(self, rate: float, trace_entry: Dict[str, object]) -> float:
        """Next (lower) rate after a failing probe at ``rate``.  The
        failing run's busy-span completion rate hints at the capacity,
        which can collapse the walk in one step when the guess was far
        off — but under *deep* overload this runtime's throughput itself
        collapses, so the hint is never trusted below a plain geometric
        ``rate / growth`` step."""
        cap = trace_entry["completion_rps"]
        hint = self.shrink * cap if math.isfinite(cap) and cap > 0 else 0.0
        return self._clamp(max(hint, rate / self.growth))

    def run(self) -> KneeSearchResult:
        trace: List[Dict[str, object]] = []
        lo = 0.0                    # highest FULL-resolution rate that
        #                             met the SLO — only such a probe may
        #                             certify the knee
        hi: Optional[float] = None  # lowest rate actually probed-and-failed
        plo: Optional[float] = None  # provisional low-res pass (guidance)
        rate = self._clamp(self.rate0)
        # -- bracket: low-resolution exponential walk to a provisional
        #    [pass, fail] straddle of the knee.  One probe is always
        #    reserved for the full-resolution phase — only that phase can
        #    certify a knee, so a bracket walk that eats the whole budget
        #    would guarantee an empty result (a budget of 1 skips
        #    bracketing entirely and spends its one probe at rate0).
        bracket_budget = self.max_probes - 1
        while len(trace) < bracket_budget:
            if self._probe(rate, "bracket", trace):
                plo = max(plo or 0.0, rate)
                if hi is not None or rate >= self.rate_ceiling:
                    break
                rate = self._clamp(rate * self.growth)
            else:
                hi = rate if hi is None else min(hi, rate)
                if plo is not None:
                    break                           # bracketed
                if hi <= self.rate_floor:
                    break                           # nothing sustainable
                nxt = self._descend(rate, trace[-1])
                if nxt >= rate:                     # floor-pinned: re-probing
                    break                           # the same rate is futile
                rate = nxt
        # -- bisect: full-resolution probes, starting by confirming the
        #    provisional pass (a short bracket probe under-samples the
        #    tail and must never certify the knee itself); when nothing
        #    passed at low resolution, descend from the failing bound
        if plo is not None:
            next_rate = plo
        elif hi is not None and hi > self.rate_floor and trace:
            next_rate = self._descend(rate, trace[-1])
        elif not trace:
            next_rate = rate        # budget of 1: single full-res probe
        else:
            next_rate = None
        while next_rate is not None and len(trace) < self.max_probes:
            rate = next_rate
            if self._probe(rate, "bisect", trace):
                lo = max(lo, rate)
                if hi is None:
                    break                           # sustainable at ceiling
            else:
                hi = rate if hi is None else min(hi, rate)
            if hi is None:
                break
            if lo > 0.0:
                if (hi - lo) / hi <= self.rel_tol:
                    break                           # bracket narrow enough
                next_rate = math.sqrt(lo * hi)
            else:
                if hi <= self.rate_floor:
                    break                           # nothing sustainable
                nxt = self._descend(rate, trace[-1])
                if nxt >= rate:
                    break
                next_rate = nxt
        if hi is None:
            # no failing bound was ever found: the knee is only a lower
            # bound — converged solely when the ceiling itself sustained
            converged = lo >= self.rate_ceiling
            hi = lo if lo > 0.0 else self._clamp(self.rate0)
        else:
            converged = (lo > 0.0 and hi >= lo
                         and (hi - lo) / max(hi, 1e-9) <= self.rel_tol)
        return KneeSearchResult(knee_rps=lo, lo_rps=lo, hi_rps=hi,
                                converged=converged, n_probes=len(trace),
                                trace=trace)


def sustainable_throughput(backend: str, fn: Optional[FunctionSpec] = None,
                           slo_p99_ms: float = 50.0, rates=None,
                           n_cores: int = 10, seed: int = 0) -> Dict[str, object]:
    """Max offered rate whose P99 stays under the SLO; fresh runtime per
    rate (open-loop correctness)."""
    fn = fn or FunctionSpec(name="aes")
    rates = rates or [250, 500, 1000, 2000, 4000, 8000, 12000, 16000, 20000, 24000]
    best, curve = 0.0, []
    for rate in rates:
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=n_cores)
        rt.deploy_blocking(fn)
        res = drive(rt, LoadSpec.single(fn.name, rate, warmup_s=0.3))
        res["offered_rps"] = float(rate)
        curve.append(res)
        ok = (res["p99_ms"] <= slo_p99_ms
              and res["achieved_rps"] >= 0.85 * rate and res["rejected"] == 0)
        if ok:
            best = max(best, rate)
    return {"sustainable_rps": best, "curve": curve}
