"""Workload generators + metric helpers for the evaluation (paper §5)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.faas import FaasdRuntime, FunctionSpec
from repro.core.simulator import Simulator


def percentile(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs), p))


@dataclasses.dataclass
class LatencySummary:
    n: int
    median_ms: float
    p99_ms: float
    mean_ms: float
    p999_ms: float

    @staticmethod
    def of(latencies_ms: List[float]) -> "LatencySummary":
        return LatencySummary(
            n=len(latencies_ms),
            median_ms=percentile(latencies_ms, 50),
            p99_ms=percentile(latencies_ms, 99),
            mean_ms=float(np.mean(latencies_ms)) if latencies_ms else float("nan"),
            p999_ms=percentile(latencies_ms, 99.9),
        )


def run_sequential(runtime: FaasdRuntime, fn_name: str, n: int = 100,
                   think_time_s: float = 0.0) -> LatencySummary:
    """Fig 5 methodology: n *sequential* invocations (closed loop)."""
    sim = runtime.sim

    def client():
        for _ in range(n):
            yield from runtime.invoke(fn_name)
            if think_time_s:
                yield sim.timeout(think_time_s)

    start = len(runtime.records)
    p = sim.process(client())
    p.completion.callbacks.append(lambda _v: sim.stop())
    sim.run()
    assert p.done, "sequential client did not finish"
    return LatencySummary.of([r.e2e * 1e3 for r in runtime.records[start:]])


def run_open_loop(runtime: FaasdRuntime, fn_name: str, rate_rps: float,
                  duration_s: float = 2.0, warmup_s: float = 0.3,
                  max_outstanding: int = 20000) -> Dict[str, float]:
    """Fig 6 methodology: Poisson open-loop arrivals at an offered rate."""
    sim = runtime.sim
    outstanding = [0]

    def arrivals():
        t_end = sim.now + duration_s
        while sim.now < t_end:
            yield sim.timeout(sim.exponential(1.0 / rate_rps))
            if outstanding[0] >= max_outstanding:
                runtime.rejected += 1
                continue
            outstanding[0] += 1

            def one():
                yield from runtime.invoke(fn_name)
                outstanding[0] -= 1

            sim.process(one())

    start_idx = len(runtime.records)
    t0 = sim.now
    sim.process(arrivals())
    sim.run(until=t0 + duration_s + 2.0)  # drain window
    recs = [r for r in runtime.records[start_idx:]
            if r.t_arrival >= t0 + warmup_s]
    lat = [r.e2e * 1e3 for r in recs]
    done_in_window = [r for r in recs if r.t_done <= t0 + duration_s + 2.0]
    ach = len(done_in_window) / max(1e-9, duration_s - warmup_s)
    summary = LatencySummary.of(lat)
    return {
        "offered_rps": rate_rps,
        "achieved_rps": ach,
        "median_ms": summary.median_ms,
        "p99_ms": summary.p99_ms,
        "n": summary.n,
        "rejected": runtime.rejected,
    }


def sustainable_throughput(backend: str, fn: Optional[FunctionSpec] = None,
                           slo_p99_ms: float = 50.0, rates=None,
                           n_cores: int = 10, seed: int = 0) -> Dict[str, object]:
    """Max offered rate whose P99 stays under the SLO; fresh runtime per
    rate (open-loop correctness)."""
    fn = fn or FunctionSpec(name="aes")
    rates = rates or [250, 500, 1000, 2000, 4000, 8000, 12000, 16000, 20000, 24000]
    best, curve = 0.0, []
    for rate in rates:
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=n_cores)
        rt.deploy_blocking(fn)
        res = run_open_loop(rt, fn.name, rate_rps=rate)
        curve.append(res)
        ok = (res["p99_ms"] <= slo_p99_ms
              and res["achieved_rps"] >= 0.85 * rate and res["rejected"] == 0)
        if ok:
            best = max(best, rate)
    return {"sustainable_rps": best, "curve": curve}
