"""Workload generators + metric helpers for the evaluation (paper §5).

Beyond the paper's two methodologies (sequential closed loop, Poisson open
loop) this module provides the arrival-process zoo the scenario suite
drives: bursty MMPP traffic (FaaSNet's dominant provisioning regime),
diurnal rate drift, trace replay, and heavy-tailed per-invocation work —
all deterministic under a fixed RNG so every stream is reproducible.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.faas import FaasdRuntime, FunctionSpec
from repro.core.simulator import Simulator


def percentile(xs: List[float], p: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs), p))


@dataclasses.dataclass
class LatencySummary:
    n: int
    median_ms: float
    p99_ms: float
    mean_ms: float
    p999_ms: float

    @staticmethod
    def of(latencies_ms: List[float]) -> "LatencySummary":
        return LatencySummary(
            n=len(latencies_ms),
            median_ms=percentile(latencies_ms, 50),
            p99_ms=percentile(latencies_ms, 99),
            mean_ms=float(np.mean(latencies_ms)) if latencies_ms else float("nan"),
            p999_ms=percentile(latencies_ms, 99.9),
        )


def run_sequential(runtime: FaasdRuntime, fn_name: str, n: int = 100,
                   think_time_s: float = 0.0) -> LatencySummary:
    """Fig 5 methodology: n *sequential* invocations (closed loop)."""
    sim = runtime.sim

    def client():
        for _ in range(n):
            yield from runtime.invoke(fn_name)
            if think_time_s:
                yield sim.timeout(think_time_s)

    start = len(runtime.records)
    p = sim.process(client())
    p.completion.callbacks.append(lambda _v: sim.stop())
    sim.run()
    assert p.done, "sequential client did not finish"
    return LatencySummary.of([r.e2e * 1e3 for r in runtime.records[start:]])


def run_open_loop(runtime: FaasdRuntime, fn_name: str, rate_rps: float,
                  duration_s: float = 2.0, warmup_s: float = 0.3,
                  max_outstanding: int = 20000,
                  on_arrival: Optional[Callable[[str], None]] = None,
                  on_done: Optional[Callable[[str], None]] = None,
                  ) -> Dict[str, float]:
    """Fig 6 methodology: Poisson open-loop arrivals at an offered rate.

    ``on_arrival``/``on_done`` fire per admitted request (rejected
    arrivals never reach them) — the hooks an autoscaler's load signal
    plugs into without scenario-specific glue.
    """
    sim = runtime.sim
    outstanding = [0]

    def arrivals():
        t_end = sim.now + duration_s
        while sim.now < t_end:
            yield sim.timeout(sim.exponential(1.0 / rate_rps))
            if outstanding[0] >= max_outstanding:
                runtime.rejected += 1
                continue
            outstanding[0] += 1
            if on_arrival is not None:
                on_arrival(fn_name)

            def one():
                yield from runtime.invoke(fn_name)
                outstanding[0] -= 1
                if on_done is not None:
                    on_done(fn_name)

            sim.process(one())

    start_idx = len(runtime.records)
    t0 = sim.now
    sim.process(arrivals())
    sim.run(until=t0 + duration_s + 2.0)  # drain window
    recs = [r for r in runtime.records[start_idx:]
            if r.t_arrival >= t0 + warmup_s]
    lat = [r.e2e * 1e3 for r in recs]
    done_in_window = [r for r in recs if r.t_done <= t0 + duration_s + 2.0]
    ach = len(done_in_window) / max(1e-9, duration_s - warmup_s)
    summary = LatencySummary.of(lat)
    return {
        "offered_rps": rate_rps,
        "achieved_rps": ach,
        "median_ms": summary.median_ms,
        "p99_ms": summary.p99_ms,
        "n": summary.n,
        "rejected": runtime.rejected,
    }


# ---------------------------------------------------------------------------
# Arrival processes.
#
# Each process turns an RNG into a sorted array of absolute arrival times in
# [0, duration_s).  Times are materialised up front (not sampled inside sim
# processes) so a stream is a pure function of (process params, rng state):
# fixed seed -> identical stream, which the determinism tests pin down.


class ArrivalProcess:
    """Base: a recipe for an arrival-time stream."""

    def times(self, rng: np.random.Generator, duration_s: float) -> np.ndarray:
        raise NotImplementedError

    def mean_rps(self) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless open-loop arrivals (the paper's Fig 6 methodology)."""
    rate_rps: float

    def times(self, rng, duration_s):
        if self.rate_rps <= 0 or duration_s <= 0:
            return np.empty(0)
        # draw in blocks: cheaper than a python loop at 10k+ rps
        out: List[np.ndarray] = []
        t, expect = 0.0, max(16, int(self.rate_rps * duration_s * 1.2))
        while t < duration_s:
            gaps = rng.exponential(1.0 / self.rate_rps, size=expect)
            ts = t + np.cumsum(gaps)
            out.append(ts)
            t = float(ts[-1])
        all_ts = np.concatenate(out)
        return all_ts[all_ts < duration_s]

    def mean_rps(self):
        return self.rate_rps


@dataclasses.dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """Two-state Markov-modulated Poisson process: quiet periods at
    ``base_rps`` punctuated by bursts at ``burst_rps`` (FaaSNet-style
    bursty multi-function provisioning traffic)."""
    base_rps: float
    burst_rps: float
    mean_quiet_s: float = 0.20
    mean_burst_s: float = 0.05
    start_in_burst: bool = False

    def times(self, rng, duration_s):
        out: List[float] = []
        t, burst = 0.0, self.start_in_burst
        seg_end = float(rng.exponential(
            self.mean_burst_s if burst else self.mean_quiet_s))
        while t < duration_s:
            rate = self.burst_rps if burst else self.base_rps
            gap = float(rng.exponential(1.0 / rate)) if rate > 0 else math.inf
            if t + gap < seg_end:
                t += gap
                if t < duration_s:
                    out.append(t)
            else:
                # exponential dwell is memoryless: restarting the gap at the
                # segment boundary keeps each segment piecewise-Poisson
                t = seg_end
                burst = not burst
                seg_end = t + float(rng.exponential(
                    self.mean_burst_s if burst else self.mean_quiet_s))
        return np.asarray(out)

    def mean_rps(self):
        tot = self.mean_quiet_s + self.mean_burst_s
        return (self.base_rps * self.mean_quiet_s
                + self.burst_rps * self.mean_burst_s) / tot


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Sinusoidally-modulated Poisson (diurnal load drift compressed to
    sim time), sampled by Lewis-Shedler thinning against the peak rate."""
    mean_rate_rps: float
    amplitude: float = 0.8          # fraction of the mean, in [0, 1]
    period_s: float = 1.0
    phase: float = -math.pi / 2     # start at the trough

    def rate_at(self, t: float) -> float:
        return self.mean_rate_rps * (1.0 + self.amplitude
                                     * math.sin(2 * math.pi * t / self.period_s
                                                + self.phase))

    def times(self, rng, duration_s):
        peak = self.mean_rate_rps * (1.0 + self.amplitude)
        if peak <= 0 or duration_s <= 0:
            return np.empty(0)
        out: List[float] = []
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / peak))
            if t >= duration_s:
                break
            if rng.random() * peak < self.rate_at(t):
                out.append(t)
        return np.asarray(out)

    def mean_rps(self):
        return self.mean_rate_rps   # the sinusoid integrates to zero


@dataclasses.dataclass(frozen=True)
class TraceReplay(ArrivalProcess):
    """Replays a recorded (or synthesised) timestamp trace, optionally
    time-compressed; arrivals beyond duration_s are dropped."""
    trace_s: Sequence[float]
    time_scale: float = 1.0

    def times(self, rng, duration_s):
        ts = np.sort(np.asarray(self.trace_s, dtype=np.float64)) * self.time_scale
        return ts[(ts >= 0) & (ts < duration_s)]

    def mean_rps(self):
        ts = np.asarray(self.trace_s, dtype=np.float64) * self.time_scale
        span = float(ts.max() - ts.min()) if len(ts) > 1 else 1.0
        return len(ts) / max(span, 1e-9)


def heavy_tailed_work(rng: np.random.Generator, median_us: float,
                      alpha: float = 1.6,
                      cap_mult: float = 200.0) -> Callable[[], float]:
    """Pareto per-invocation CPU work (heavy-tailed payload sizes): returns
    a sampler usable as ``FunctionSpec.work_us``.  ``median_us`` pins the
    distribution median; ``cap_mult`` truncates the tail so a single
    invocation cannot exceed median*cap_mult."""
    xm = median_us / (2.0 ** (1.0 / alpha))
    cap = median_us * cap_mult

    def sample() -> float:
        u = 1.0 - rng.random()          # u in (0, 1]
        return float(min(xm * u ** (-1.0 / alpha), cap))

    return sample


# ---------------------------------------------------------------------------
# Generic open-loop driver: any arrival process over a multi-function mix.


def run_mixed_open_loop(runtime: FaasdRuntime, fn_names: Sequence[str],
                        weights: Sequence[float], arrivals: ArrivalProcess,
                        duration_s: float, warmup_frac: float = 0.2,
                        max_outstanding: int = 20000,
                        drain_s: float = 2.0,
                        on_arrival: Optional[Callable[[str], None]] = None,
                        on_done: Optional[Callable[[str], None]] = None,
                        ) -> Dict[str, object]:
    """Open-loop run of ``arrivals`` over a weighted function mix.

    Generalizes ``run_open_loop`` (single fn, Poisson) to arbitrary arrival
    processes and multi-tenant mixes; returns overall + per-function stats.
    ``on_arrival``/``on_done`` fire per admitted request (rejected
    arrivals never reach them) so any open-loop driver can feed an
    autoscaler's load signal.
    """
    sim = runtime.sim
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    t0 = sim.now
    rel_times = arrivals.times(sim.rng, duration_s)
    picks = sim.rng.choice(len(fn_names), size=len(rel_times), p=w)
    outstanding = [0]
    rejected0 = runtime.rejected

    def driver():
        for rel_t, pick in zip(rel_times, picks):
            yield sim.timeout(t0 + float(rel_t) - sim.now)
            if outstanding[0] >= max_outstanding:
                runtime.rejected += 1
                continue
            outstanding[0] += 1
            if on_arrival is not None:
                on_arrival(fn_names[pick])

            def one(fn=fn_names[pick]):
                yield from runtime.invoke(fn)
                outstanding[0] -= 1
                if on_done is not None:
                    on_done(fn)

            sim.process(one())

    start_idx = len(runtime.records)
    sim.process(driver())
    sim.run(until=t0 + duration_s + drain_s)
    warmup_s = warmup_frac * duration_s
    recs = [r for r in runtime.records[start_idx:]
            if r.t_arrival >= t0 + warmup_s]
    done = [r for r in recs if r.t_done <= t0 + duration_s + drain_s]
    summary = LatencySummary.of([r.e2e * 1e3 for r in recs])
    per_fn: Dict[str, LatencySummary] = {}
    for name in fn_names:
        lat = [r.e2e * 1e3 for r in recs if r.fn == name]
        if lat:
            per_fn[name] = LatencySummary.of(lat)
    return {
        "offered_rps": len(rel_times) / max(duration_s, 1e-9),
        "achieved_rps": len(done) / max(1e-9, duration_s - warmup_s),
        "median_ms": summary.median_ms,
        "p99_ms": summary.p99_ms,
        "mean_ms": summary.mean_ms,
        "p999_ms": summary.p999_ms,
        "n": summary.n,
        "rejected": runtime.rejected - rejected0,
        "per_fn": per_fn,
        "latencies_ms": [r.e2e * 1e3 for r in recs],
    }


def knee_of_curve(curve: List[Dict[str, float]], slo_p99_ms: float,
                  min_achieved_frac: float = 0.85,
                  rate_key: str = "nominal_rps") -> float:
    """Max offered rate whose P99 meets the SLO with no rejects and
    achieved throughput within ``min_achieved_frac`` of offered.

    Rows without a positive nominal rate (e.g. trace replay, where the
    trace fixes the rate) fall back to the measured offered rate so the
    achieved-fraction check still binds."""
    best = 0.0
    for r in curve:
        rate = float(r.get(rate_key) or r["offered_rps"])
        if (r["p99_ms"] <= slo_p99_ms and r.get("rejected", 0) == 0
                and r["achieved_rps"] >= min_achieved_frac * rate):
            best = max(best, rate)
    return best


def sustainable_throughput(backend: str, fn: Optional[FunctionSpec] = None,
                           slo_p99_ms: float = 50.0, rates=None,
                           n_cores: int = 10, seed: int = 0) -> Dict[str, object]:
    """Max offered rate whose P99 stays under the SLO; fresh runtime per
    rate (open-loop correctness)."""
    fn = fn or FunctionSpec(name="aes")
    rates = rates or [250, 500, 1000, 2000, 4000, 8000, 12000, 16000, 20000, 24000]
    best, curve = 0.0, []
    for rate in rates:
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=n_cores)
        rt.deploy_blocking(fn)
        res = run_open_loop(rt, fn.name, rate_rps=rate)
        curve.append(res)
        ok = (res["p99_ms"] <= slo_p99_ms
              and res["achieved_rps"] >= 0.85 * rate and res["rejected"] == 0)
        if ok:
            best = max(best, rate)
    return {"sustainable_rps": best, "curve": curve}
