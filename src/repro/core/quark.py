"""Quark-style secure container runtime (arXiv:2309.12624), modeled.

Quark runs containers on a user-space guest kernel (QKernel) behind a
lightweight hypervisor boundary (QVisor).  Lifecycle-wise it is
containerd-class — the provider still talks to a container runtime over
ms-scale RPCs and cold start pays container create *plus* a guest-kernel
boot — but every syscall and every packet crosses the interception layer,
so the datapath and execution overheads grow relative to plain
containers.  This occupies the "more isolation, same control plane"
corner of the backend trade-off space.
"""
from __future__ import annotations

from repro.core.backends import ColdStartModel, register_backend
from repro.core.containerd import Containerd
from repro.core.latency import (QUARK_COLDSTART_MS, QUARK_QUERY_MS,
                                QUARK_RUNTIME, QUARK_STACK)


@register_backend
class Quark(Containerd):
    """Containerd-class lifecycle with per-syscall/datapath interception
    costs and a guest-kernel boot on the cold path."""

    name = "quark"
    runtime = QUARK_RUNTIME
    stack_costs = QUARK_STACK
    coldstart = ColdStartModel(deploy_ms=QUARK_COLDSTART_MS,
                               scale_factor=0.6,
                               query_ms=QUARK_QUERY_MS)
