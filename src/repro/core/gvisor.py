"""gVisor-style sandboxed container runtime (runsc), modeled.

Functions run behind the Sentry — a user-space kernel written in Go that
intercepts every syscall and owns a user-space netstack.  The control
plane is containerd-shaped (runsc is an OCI runtime), but the cold start
is lighter than a container-on-VM or quark's guest-kernel boot: the
Sentry comes up without booting a Linux guest.  Warm-path costs land
between ``containerd`` and ``quark``: every syscall and every packet pay
the interception tax, but less than quark's full QKernel/QVisor stack.

The ``platform`` knob picks how interception happens, mirroring runsc's
``--platform`` flag:

* ``"kvm"`` (default) — syscalls trap via lightweight VM exits; the
  registered cost tables.
* ``"ptrace"`` — every syscall costs two context switches through the
  ptrace stop machinery; several times more per-syscall overhead and a
  slower netstack (the portable-but-slow fallback).

Both platforms share the lifecycle and cold-start class; only the cost
tables differ, so the knob is a constructor argument rather than a second
registry entry.
"""
from __future__ import annotations

from repro.core.backends import ColdStartModel, register_backend
from repro.core.containerd import Containerd
from repro.core.latency import (GVISOR_COLDSTART_MS, GVISOR_KVM_RUNTIME,
                                GVISOR_KVM_STACK, GVISOR_PTRACE_RUNTIME,
                                GVISOR_PTRACE_STACK, GVISOR_QUERY_MS)
from repro.core.scheduler import PollingModel
from repro.core.simulator import Simulator


@register_backend
class GVisor(Containerd):
    """Containerd-class lifecycle with Sentry syscall/netstack interception
    costs; ``platform`` selects the KVM or ptrace cost tables."""

    name = "gvisor"
    runtime = GVISOR_KVM_RUNTIME
    stack_costs = GVISOR_KVM_STACK
    coldstart = ColdStartModel(deploy_ms=GVISOR_COLDSTART_MS,
                               scale_factor=0.6,
                               query_ms=GVISOR_QUERY_MS)

    PLATFORMS = {
        "kvm": (GVISOR_KVM_RUNTIME, GVISOR_KVM_STACK),
        "ptrace": (GVISOR_PTRACE_RUNTIME, GVISOR_PTRACE_STACK),
    }

    def __init__(self, sim: Simulator, *, n_cores: int = 10,
                 polling_model: PollingModel = PollingModel.CENTRALIZED,
                 platform: str = "kvm"):
        try:
            runtime, stack = self.PLATFORMS[platform]
        except KeyError:
            raise ValueError(
                f"unknown gVisor platform {platform!r}; "
                f"have {sorted(self.PLATFORMS)}") from None
        self.platform = platform
        # instance attributes shadow the class-level (kvm) cost tables
        # before the base constructor builds the CorePool/NetStack from them
        self.runtime = runtime
        self.stack_costs = stack
        super().__init__(sim, n_cores=n_cores, polling_model=polling_model)
