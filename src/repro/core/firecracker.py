"""Firecracker-style microVM backend (NSDI '20), modeled.

Each function runs in its own minimal VM: strong isolation behind a slim
VMM, a virtio datapath through two network stacks, and a containerd-class
control plane.  The design's distinctive lifecycle is the *snapshot
cache*: the first cold start of a function pays a full microVM boot
(~125 ms) and warms a per-function memory/device snapshot; every later
cold start — a redeploy or a scale-up replica — restores from that
snapshot in single-digit ms.  ``remove`` tears the function down
entirely, snapshot included, so the next deploy boots from scratch; the
cache holds at most ``snapshot_capacity`` snapshots and evicts the
least-recently-used one beyond that (snapshots are hundreds of MB of
guest memory — a host cannot keep one per function forever).

This fills the spectrum between ``wasm`` (instant cold start, weak
isolation story) and ``quark``/``gvisor`` (strong isolation, slow
control plane): VM-grade isolation whose *second* cold start is almost
junctiond-fast.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Generator, Optional

from repro.core.backends import SnapshotColdStartModel, register_backend
from repro.core.containerd import Containerd, ContainerRecord
from repro.core.latency import (FIRECRACKER_BOOT_MS, FIRECRACKER_QUERY_MS,
                                FIRECRACKER_RESTORE_MS, FIRECRACKER_RUNTIME,
                                FIRECRACKER_SNAPSHOT_SAVE_MS,
                                FIRECRACKER_STACK)
from repro.core.scheduler import PollingModel
from repro.core.simulator import Simulator

# Snapshots pin guest memory on the host; a worker keeps a bounded pool.
DEFAULT_SNAPSHOT_CAPACITY = 32


@dataclasses.dataclass
class Snapshot:
    """A pre-warmed memory/device snapshot of one function's booted guest."""
    fn: str
    taken_at: float


@dataclasses.dataclass
class MicroVMRecord(ContainerRecord):
    restored: bool = False    # last deploy was a snapshot restore, not a boot


class SnapshotCache:
    """Per-function snapshot store with LRU capacity eviction.

    ``get`` counts as a use (refreshes recency); ``put`` evicts the
    least-recently-used entry once the cache is full.  ``evict`` is the
    explicit-removal path (function removed -> snapshot must go too).
    """

    def __init__(self, capacity: int = DEFAULT_SNAPSHOT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"snapshot capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.evictions = 0
        self._entries: "collections.OrderedDict[str, Snapshot]" = \
            collections.OrderedDict()

    def get(self, fn: str) -> Optional[Snapshot]:
        snap = self._entries.get(fn)
        if snap is not None:
            self._entries.move_to_end(fn)
        return snap

    def put(self, snap: Snapshot) -> None:
        self._entries[snap.fn] = snap
        self._entries.move_to_end(snap.fn)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def evict(self, fn: str) -> bool:
        return self._entries.pop(fn, None) is not None

    def __contains__(self, fn: str) -> bool:
        return fn in self._entries

    def __len__(self) -> int:
        return len(self._entries)


@register_backend
class Firecracker(Containerd):
    """Container-shaped control plane over per-function microVMs with a
    two-mode cold path: full boot warms the snapshot, later cold starts
    restore from it (until ``remove`` evicts it or capacity pressure
    pushes it out)."""

    name = "firecracker"
    runtime = FIRECRACKER_RUNTIME
    stack_costs = FIRECRACKER_STACK
    coldstart = SnapshotColdStartModel(
        deploy_ms=FIRECRACKER_BOOT_MS,
        query_ms=FIRECRACKER_QUERY_MS,
        restore_ms=FIRECRACKER_RESTORE_MS,
        save_ms=FIRECRACKER_SNAPSHOT_SAVE_MS)

    def __init__(self, sim: Simulator, *, n_cores: int = 10,
                 polling_model: PollingModel = PollingModel.CENTRALIZED,
                 snapshot_capacity: int = DEFAULT_SNAPSHOT_CAPACITY):
        super().__init__(sim, n_cores=n_cores, polling_model=polling_model)
        self.snapshots = SnapshotCache(snapshot_capacity)
        self.boots = 0
        self.restores = 0

    # -- the two-mode cold path -------------------------------------------
    def _cold_start_one(self, fn_name: str) -> Generator:
        """Bring up one microVM for ``fn_name``: restore when a snapshot
        exists, else full boot + snapshot warm."""
        if self.snapshots.get(fn_name) is not None:
            yield self.sim.timeout(self.coldstart.restore_seconds)
            self.restores += 1
            return True
        # full boot + snapshot save: warming the cache costs extra over
        # a bare boot (pause + serialize memory/device state)
        yield self.sim.timeout(self.coldstart.boot_seconds)
        self.snapshots.put(Snapshot(fn=fn_name, taken_at=self.sim.now))
        self.boots += 1
        return False

    # -- lifecycle --------------------------------------------------------
    def deploy(self, fn_name: str, *, scale: int = 1, max_cores: int = 2,
               isolate_replicas: bool = False) -> Generator:
        # redeploy releases the old microVMs but NOT the snapshot: it is
        # keyed by the function image, so a config update restores fast
        super().remove(fn_name)     # the runtime-resource-only teardown
        restored = yield from self._cold_start_one(fn_name)
        for _ in range(1, scale):
            # extra replicas restore from the snapshot just warmed
            yield from self._cold_start_one(fn_name)
        self.records[fn_name] = MicroVMRecord(
            name=fn_name, ip=f"10.62.0.{len(self.records) + 2}", port=8080,
            replicas=scale, restored=restored)
        self.deploys += 1

    def scale(self, fn_name: str, replicas: int) -> Generator:
        rec = self._require(fn_name)
        # new replicas cold-start one by one: the first re-warms the
        # snapshot if capacity eviction dropped it, the rest restore;
        # scale-down reaps microVMs at no init cost
        for _ in range(replicas - rec.replicas):
            yield from self._cold_start_one(fn_name)
        rec.replicas = replicas

    def remove(self, fn_name: str) -> None:
        """Full teardown: microVMs *and* the function's snapshot — the
        next deploy pays a fresh boot."""
        super().remove(fn_name)
        self.snapshots.evict(fn_name)
