"""Mamba selective-scan Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: instead of one thread-block per
(batch, channel-chunk) with warp shuffles, the grid walks
(batch, d_inner-block, seq-block) with the seq-block dimension minor and
sequential, carrying the (bd, ds) SSM state in VMEM scratch across
sequence blocks.  Inside a block a ``fori_loop`` steps time; every state
update is a (bd, ds) vector op on the VPU — the state never leaves VMEM,
which is the whole point (the CUDA version keeps it in registers).

Inputs: dt, dtx (B, S, di); Bm, Cm (B, S, ds); A (di, ds).
Outputs: y (B, S, di), h_last (B, di, ds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(dt_ref, dtx_ref, B_ref, C_ref, A_ref, y_ref, h_ref, h_scr,
                 *, block_s: int):
    js = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(js == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = A_ref[...].astype(jnp.float32)            # (bd, ds)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)   # (bd,)
        dtx_t = dtx_ref[0, t].astype(jnp.float32)
        B_t = B_ref[0, t].astype(jnp.float32)     # (ds,)
        C_t = C_ref[0, t].astype(jnp.float32)
        a = jnp.exp(dt_t[:, None] * A)            # (bd, ds)
        b = dtx_t[:, None] * B_t[None, :]
        h = a * h + b
        y_ref[0, t] = jnp.sum(h * C_t[None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_scr[...])
    h_scr[...] = h

    @pl.when(js == ns - 1)
    def _finish():
        h_ref[0] = h.astype(h_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_s", "interpret"))
def mamba_scan(dt: jnp.ndarray, dtx: jnp.ndarray, Bm: jnp.ndarray,
               Cm: jnp.ndarray, A: jnp.ndarray, *, block_d: int = 256,
               block_s: int = 256, interpret: bool = False):
    B, S, di = dt.shape
    ds = Bm.shape[-1]
    bd = min(block_d, di)
    bs = min(block_s, S)
    assert di % bd == 0 and S % bs == 0, (di, bd, S, bs)

    y, h_last = pl.pallas_call(
        functools.partial(_scan_kernel, block_s=bs),
        grid=(B, di // bd, S // bs),
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, i, j: (b, j, i)),
            pl.BlockSpec((1, bs, bd), lambda b, i, j: (b, j, i)),
            pl.BlockSpec((1, bs, ds), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bs, ds), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((bd, ds), lambda b, i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, i, j: (b, j, i)),
            pl.BlockSpec((1, bd, ds), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), dt.dtype),
            jax.ShapeDtypeStruct((B, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, ds), jnp.float32)],
        interpret=interpret,
    )(dt, dtx, Bm, Cm, A)
    return y, h_last
