"""AES-128-CTR Pallas kernel — the paper's benchmark function (vSwarm AES
over a 600-byte input) as a TPU micro-function.

TPU adaptation: the x86 version uses AES-NI; TPUs have no AES ISA, so the
kernel vectorises table-based AES over counter blocks: the state is a
(block_n, 16) int32 tile in VMEM, S-box/xtime are 256-entry VMEM tables
(gathered with ``jnp.take``), and all 10 rounds run per grid step.  This
is of course not how one would serve AES in production — it exists to
deploy the *paper's own benchmark function* on the TPU serving runtime,
keeping the FaaS pipeline end-to-end real.

plaintext: (N, 16) int32 bytes; round_keys: (11, 16); -> ciphertext (N, 16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import aes_key_expand  # noqa: F401


def _shift_rows(s: jnp.ndarray) -> jnp.ndarray:
    """AES ShiftRows without gather constants: state is (bn, 16) in
    column-major byte order; row r rotates left by r across columns."""
    s4 = s.reshape(s.shape[0], 4, 4)           # (bn, col, row)
    rows = [jnp.roll(s4[:, :, r], -r, axis=1) for r in range(4)]
    return jnp.stack(rows, axis=-1).reshape(s.shape)


def _aes_kernel(pt_ref, ctr_ref, rk_ref, sbox_ref, xt_ref, ct_ref):
    s = ctr_ref[...]                           # (bn, 16) counter blocks
    rk = rk_ref[...]                           # (11, 16)
    sbox = sbox_ref[...]
    xt = xt_ref[...]

    def sub_shift(s):
        s = jnp.take(sbox, s, axis=0)
        return _shift_rows(s)

    def mix(s):
        s4 = s.reshape(s.shape[0], 4, 4)
        a0, a1, a2, a3 = s4[..., 0], s4[..., 1], s4[..., 2], s4[..., 3]
        x0, x1, x2, x3 = (jnp.take(xt, a, axis=0) for a in (a0, a1, a2, a3))
        b0 = x0 ^ (a1 ^ x1) ^ a2 ^ a3
        b1 = a0 ^ x1 ^ (a2 ^ x2) ^ a3
        b2 = a0 ^ a1 ^ x2 ^ (a3 ^ x3)
        b3 = (a0 ^ x0) ^ a1 ^ a2 ^ x3
        return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(s.shape)

    s = s ^ rk[0][None]
    for rnd in range(1, 10):
        s = mix(sub_shift(s)) ^ rk[rnd][None]
    s = sub_shift(s) ^ rk[10][None]
    ct_ref[...] = pt_ref[...] ^ s


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def aes_ctr(plaintext: jnp.ndarray, round_keys: jnp.ndarray, *,
            nonce: int = 0, block_n: int = 128,
            interpret: bool = False) -> jnp.ndarray:
    from repro.kernels.ref import SBOX, XTIME
    N = plaintext.shape[0]
    bn = min(block_n, max(1, N))
    pad = (-N) % bn
    if pad:
        plaintext = jnp.pad(plaintext, ((0, pad), (0, 0)))
    Np = N + pad
    ctr = jnp.arange(Np, dtype=jnp.int32) + nonce
    shifts = jnp.arange(3, -1, -1, dtype=jnp.int32) * 8
    ctr_bytes = ((ctr[:, None] >> shifts[None, :]) & 0xFF).astype(jnp.int32)
    ctr_blocks = jnp.concatenate([jnp.zeros((Np, 12), jnp.int32), ctr_bytes], axis=1)

    ct = pl.pallas_call(
        _aes_kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((bn, 16), lambda i: (i, 0)),
            pl.BlockSpec((bn, 16), lambda i: (i, 0)),
            pl.BlockSpec((11, 16), lambda i: (0, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Np, 16), jnp.int32),
        interpret=interpret,
    )(plaintext, ctr_blocks, round_keys, SBOX, XTIME)
    return ct[:N]
