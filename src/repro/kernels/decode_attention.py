"""GQA decode attention Pallas TPU kernel: ONE query token per sequence
against a long KV cache (the serve_step hot loop).

All G query heads of one KV head are processed together (an (G, d) x
(d, bk) MXU matmul per KV block), with online softmax carried in VMEM
scratch across the sequential KV-block grid dimension.  Masking comes
from a per-(batch) valid-length vector (ring-buffer slots may be invalid
early on).

Layouts: q (B, Hq, d); k/v (B, T, Hkv, d); valid (B, T) int32 -> (B, Hq, d).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float):
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, d)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (bk, d)
    v = v_ref[0, :, 0].astype(jnp.float32)       # (bk, d)
    valid = valid_ref[0] != 0                    # (bk,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, bk)
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(jk == nk - 1)
    def _finish():
        l = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     valid: jnp.ndarray, *, block_k: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, d); k/v: (B, T, Hkv, d); valid: (B, T) bool/int."""
    B, Hq, d = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = d ** -0.5
    bk = min(block_k, T)
    pad = (-T) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        valid = jnp.pad(valid.astype(jnp.int32), ((0, 0), (0, pad)))
    Tp = T + pad
    qg = q.reshape(B, Hkv, G, d)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=(B, Hkv, Tp // bk),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, valid.astype(jnp.int32))
    return out.reshape(B, Hq, d)
