"""RWKV6 wkv-recurrence Pallas TPU kernel.

Grid: (batch, head, seq-block), seq-block minor and sequential; the
(hd, hd) per-head state matrix lives in VMEM scratch across sequence
blocks.  Each time step is rank-1 state update + matrix-vector product on
the VPU; hd=64 keeps the state lane-aligned.

Inputs: r, k, v, w (B, T, H, hd) (w = per-channel decay in (0,1)),
u (H, hd) bonus.  Outputs: o (B, T, H, hd), S_last (B, H, hd, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, s_scr,
                *, block_t: int):
    jt = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(jt == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)              # (hd,)

    def step(t, S):
        rt = r_ref[0, t, 0].astype(jnp.float32)   # (hd,)
        kt = k_ref[0, t, 0].astype(jnp.float32)
        vt = v_ref[0, t, 0].astype(jnp.float32)
        wt = w_ref[0, t, 0].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]            # (hd, hd)
        eff = S + u[:, None] * kv
        o_ref[0, t, 0] = jnp.sum(eff * rt[:, None], axis=0).astype(o_ref.dtype)
        return wt[:, None] * S + kv

    S = jax.lax.fori_loop(0, block_t, step, s_scr[...])
    s_scr[...] = S

    @pl.when(jt == nt - 1)
    def _finish():
        s_ref[0, 0] = S.astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray, *, block_t: int = 256,
               interpret: bool = False):
    B, T, H, hd = r.shape
    bt = min(block_t, T)
    assert T % bt == 0, (T, bt)

    o, s_last = pl.pallas_call(
        functools.partial(_wkv_kernel, block_t=bt),
        grid=(B, H, T // bt),
        in_specs=[
            pl.BlockSpec((1, bt, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, hd), lambda b, h, j: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return o, s_last
