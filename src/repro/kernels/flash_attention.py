"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax blockwise attention with causal + sliding-window masking
and GQA head grouping.  TPU adaptation: the grid's minor dimension walks
KV blocks *sequentially* (TPU grids are sequential per core), carrying the
running max / denominator / accumulator in VMEM scratch; block shapes are
MXU-aligned (multiples of 128 on the matmul dims).

Layouts: q (B, Hq, S, d), k/v (B, Hkv, T, d) -> o (B, Hq, S, d).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 block_q: int, block_k: int, kv_len: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # absolute positions: queries live at [T - S .. T) when a prefix is cached
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                    # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                    # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    B, Hq, S, d = q.shape
    _, Hkv, T, _ = k.shape
    G = Hq // Hkv
    scale = d ** -0.5
    bq = min(block_q, S)
    bk = min(block_k, T)
    q_pad = (-S) % bq
    k_pad = (-T) % bk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, k_pad), (0, 0)))
    Sp, Tp = S + q_pad, T + k_pad

    grid = (B, Hq, Sp // bq, Tp // bk)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, kv_len=T, q_offset=T - S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
