"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Flash attention (prefill, causal + optional sliding window, GQA)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Hq, S, d); k, v: (B, Hkv, T, d) -> (B, Hq, S, d)."""
    B, Hq, S, d = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kp <= qp + (T - S)     # allow prefix cache offset
    if window is not None:
        mask &= kp > qp + (T - S) - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA decode attention (one token vs KV cache)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid: jnp.ndarray,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Hq, d); k, v: (B, T, Hkv, d); valid: (B, T) bool -> (B, Hq, d)."""
    B, Hq, d = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(B, Hkv, G, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba selective scan


def mamba_scan_ref(dt: jnp.ndarray, dtx: jnp.ndarray, Bm: jnp.ndarray,
                   Cm: jnp.ndarray, A: jnp.ndarray,
                   h0: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """dt, dtx: (B, S, di); Bm, Cm: (B, S, ds); A: (di, ds).
    Returns y: (B, S, di), h_T: (B, di, ds)."""
    Bsz, S, di = dt.shape
    ds = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, di, ds), jnp.float32)

    def step(h, t):
        a = jnp.exp(dt[:, t, :, None] * A)
        b = dtx[:, t, :, None] * Bm[:, t, None, :]
        h = a * h + b
        y = jnp.einsum("bde,be->bd", h, Cm[:, t])
        return h, y

    h_last, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), h_last


# ---------------------------------------------------------------------------
# RWKV6 wkv recurrence


def rwkv6_scan_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   w: jnp.ndarray, u: jnp.ndarray,
                   S0: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w: (B, T, H, hd); u: (H, hd).  Returns o: (B,T,H,hd), S_T."""
    B, T, H, hd = r.shape
    if S0 is None:
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(S, t):
        kv = k[:, t, :, :, None] * v[:, t, :, None, :]        # (B,H,hd,hd)
        eff = S + u[None, :, :, None] * kv
        o = jnp.einsum("bhij,bhi->bhj", eff, r[:, t])
        S = w[:, t, :, :, None] * S + kv
        return S, o

    S_last, os_ = jax.lax.scan(step, S0, jnp.arange(T))
    return jnp.moveaxis(os_, 0, 1), S_last


# ---------------------------------------------------------------------------
# MoE grouped matmul


def moe_gmm_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# AES-128-CTR (the paper's benchmark function) — table-based reference


def _aes_tables():
    import numpy as np
    sbox = np.array([
        0x63,0x7c,0x77,0x7b,0xf2,0x6b,0x6f,0xc5,0x30,0x01,0x67,0x2b,0xfe,0xd7,0xab,0x76,
        0xca,0x82,0xc9,0x7d,0xfa,0x59,0x47,0xf0,0xad,0xd4,0xa2,0xaf,0x9c,0xa4,0x72,0xc0,
        0xb7,0xfd,0x93,0x26,0x36,0x3f,0xf7,0xcc,0x34,0xa5,0xe5,0xf1,0x71,0xd8,0x31,0x15,
        0x04,0xc7,0x23,0xc3,0x18,0x96,0x05,0x9a,0x07,0x12,0x80,0xe2,0xeb,0x27,0xb2,0x75,
        0x09,0x83,0x2c,0x1a,0x1b,0x6e,0x5a,0xa0,0x52,0x3b,0xd6,0xb3,0x29,0xe3,0x2f,0x84,
        0x53,0xd1,0x00,0xed,0x20,0xfc,0xb1,0x5b,0x6a,0xcb,0xbe,0x39,0x4a,0x4c,0x58,0xcf,
        0xd0,0xef,0xaa,0xfb,0x43,0x4d,0x33,0x85,0x45,0xf9,0x02,0x7f,0x50,0x3c,0x9f,0xa8,
        0x51,0xa3,0x40,0x8f,0x92,0x9d,0x38,0xf5,0xbc,0xb6,0xda,0x21,0x10,0xff,0xf3,0xd2,
        0xcd,0x0c,0x13,0xec,0x5f,0x97,0x44,0x17,0xc4,0xa7,0x7e,0x3d,0x64,0x5d,0x19,0x73,
        0x60,0x81,0x4f,0xdc,0x22,0x2a,0x90,0x88,0x46,0xee,0xb8,0x14,0xde,0x5e,0x0b,0xdb,
        0xe0,0x32,0x3a,0x0a,0x49,0x06,0x24,0x5c,0xc2,0xd3,0xac,0x62,0x91,0x95,0xe4,0x79,
        0xe7,0xc8,0x37,0x6d,0x8d,0xd5,0x4e,0xa9,0x6c,0x56,0xf4,0xea,0x65,0x7a,0xae,0x08,
        0xba,0x78,0x25,0x2e,0x1c,0xa6,0xb4,0xc6,0xe8,0xdd,0x74,0x1f,0x4b,0xbd,0x8b,0x8a,
        0x70,0x3e,0xb5,0x66,0x48,0x03,0xf6,0x0e,0x61,0x35,0x57,0xb9,0x86,0xc1,0x1d,0x9e,
        0xe1,0xf8,0x98,0x11,0x69,0xd9,0x8e,0x94,0x9b,0x1e,0x87,0xe9,0xce,0x55,0x28,0xdf,
        0x8c,0xa1,0x89,0x0d,0xbf,0xe6,0x42,0x68,0x41,0x99,0x2d,0x0f,0xb0,0x54,0xbb,0x16],
        dtype=np.int32)
    # GF(2^8) xtime table for MixColumns
    xt = np.zeros(256, dtype=np.int32)
    for i in range(256):
        x = i << 1
        if x & 0x100:
            x ^= 0x11b
        xt[i] = x
    rcon = np.array([0x01,0x02,0x04,0x08,0x10,0x20,0x40,0x80,0x1b,0x36], np.int32)
    return jnp.asarray(sbox), jnp.asarray(xt), jnp.asarray(rcon)


SBOX, XTIME, RCON = _aes_tables()


def aes_key_expand(key_bytes: jnp.ndarray) -> jnp.ndarray:
    """key: (16,) int32 -> round keys (11, 16) int32."""
    w = [key_bytes[i * 4:(i + 1) * 4] for i in range(4)]
    for i in range(4, 44):
        t = w[i - 1]
        if i % 4 == 0:
            t = jnp.roll(t, -1)
            t = SBOX[t]
            t = t.at[0].set(t[0] ^ RCON[i // 4 - 1])
        w.append(w[i - 4] ^ t)
    rk = jnp.stack(w).reshape(11, 16)
    return rk


def _mix_columns(s: jnp.ndarray) -> jnp.ndarray:
    """s: (..., 16) column-major AES state bytes."""
    s = s.reshape(s.shape[:-1] + (4, 4))           # (..., col, row)
    a0, a1, a2, a3 = s[..., 0], s[..., 1], s[..., 2], s[..., 3]
    x0, x1, x2, x3 = XTIME[a0], XTIME[a1], XTIME[a2], XTIME[a3]
    b0 = x0 ^ (a1 ^ x1) ^ a2 ^ a3
    b1 = a0 ^ x1 ^ (a2 ^ x2) ^ a3
    b2 = a0 ^ a1 ^ x2 ^ (a3 ^ x3)
    b3 = (a0 ^ x0) ^ a1 ^ a2 ^ x3
    return jnp.stack([b0, b1, b2, b3], axis=-1).reshape(s.shape[:-2] + (16,))


_SHIFT_ROWS = jnp.asarray([0, 5, 10, 15, 4, 9, 14, 3, 8, 13, 2, 7, 12, 1, 6, 11])


def aes_encrypt_block_ref(block: jnp.ndarray, round_keys: jnp.ndarray) -> jnp.ndarray:
    """block: (..., 16) int32 bytes; round_keys (11, 16)."""
    s = block ^ round_keys[0]
    for rnd in range(1, 10):
        s = SBOX[s]
        s = s[..., _SHIFT_ROWS]
        s = _mix_columns(s)
        s = s ^ round_keys[rnd]
    s = SBOX[s]
    s = s[..., _SHIFT_ROWS]
    return s ^ round_keys[10]


def aes_ctr_ref(plaintext: jnp.ndarray, key_bytes: jnp.ndarray,
                nonce: int = 0) -> jnp.ndarray:
    """plaintext: (N, 16) int32 byte blocks -> ciphertext (N, 16)."""
    n = plaintext.shape[0]
    rk = aes_key_expand(key_bytes)
    ctr = jnp.arange(n, dtype=jnp.int32) + nonce
    # counter block: 12 zero bytes then big-endian 32-bit counter
    shifts = jnp.arange(3, -1, -1, dtype=jnp.int32) * 8
    ctr_bytes = ((ctr[:, None] >> shifts[None, :]) & 0xFF).astype(jnp.int32)
    blocks = jnp.concatenate(
        [jnp.zeros((n, 12), jnp.int32), ctr_bytes], axis=1)
    keystream = aes_encrypt_block_ref(blocks, rk)
    return plaintext ^ keystream
