from repro.kernels import ops, ref
from repro.kernels.ops import (aes_ctr, decode_attention, flash_attention,
                               mamba_scan, moe_gmm, rwkv6_scan)

__all__ = ["ops", "ref", "aes_ctr", "decode_attention", "flash_attention",
           "mamba_scan", "moe_gmm", "rwkv6_scan"]
