"""MoE grouped (expert-batched) matmul Pallas TPU kernel.

Computes y[e] = x[e] @ w[e] for every expert — the FFN inner loop of the
capacity-based MoE dispatch.  Classic MXU tiling: grid
(E, C/bc, F/bf, D/bd) with the contraction (D) dimension minor and
sequential, accumulating in fp32 VMEM scratch.

x: (E, C, D); w: (E, D, F) -> y: (E, C, F).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, y_ref, acc_scr):
    kd = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(kd == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _finish():
        y_ref[0] = acc_scr[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def moe_gmm(x: jnp.ndarray, w: jnp.ndarray, *, block_c: int = 128,
            block_f: int = 128, block_d: int = 512,
            interpret: bool = False) -> jnp.ndarray:
    E, C, D = x.shape
    _, _, F = w.shape
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    pc, pf, pd = (-C) % bc, (-F) % bf, (-D) % bd
    if pc or pd:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, pd)))
    if pd or pf:
        w = jnp.pad(w, ((0, 0), (0, pd), (0, pf)))
    Cp, Fp, Dp = C + pc, F + pf, D + pd

    y = pl.pallas_call(
        _gmm_kernel,
        grid=(E, Cp // bc, Fp // bf, Dp // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, Fp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return y[:, :C, :F]
