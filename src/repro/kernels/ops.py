"""Jit'd public wrappers for every Pallas kernel, with an ``xla`` fallback
(the oracle path) selectable via backend= — the model code calls these so
the same model runs on CPU (xla / interpret) and TPU (pallas).
"""
from __future__ import annotations

import os
from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.aes_ctr import aes_ctr as _aes_ctr_pallas
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.mamba_scan import mamba_scan as _mamba_pallas
from repro.kernels.moe_gmm import moe_gmm as _gmm_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan as _rwkv_pallas


def default_backend() -> str:
    """'pallas' on TPU, 'xla' elsewhere; override with REPRO_KERNEL_BACKEND
    ('pallas_interpret' validates kernels on CPU)."""
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    import jax
    return "pallas" if jax.devices()[0].platform == "tpu" else "xla"


def _resolve(backend: Optional[str]):
    b = backend or default_backend()
    if b not in ("pallas", "pallas_interpret", "xla"):
        raise ValueError(f"unknown kernel backend {b!r}")
    return b


def flash_attention(q, k, v, *, causal=True, window=None, backend=None):
    b = _resolve(backend)
    if b == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=(b == "pallas_interpret"))


def decode_attention(q, k, v, valid, *, backend=None):
    b = _resolve(backend)
    if b == "xla":
        return ref.decode_attention_ref(q, k, v, valid)
    return _decode_pallas(q, k, v, valid, interpret=(b == "pallas_interpret"))


def mamba_scan(dt, dtx, Bm, Cm, A, *, backend=None):
    b = _resolve(backend)
    if b == "xla":
        return ref.mamba_scan_ref(dt, dtx, Bm, Cm, A)
    return _mamba_pallas(dt, dtx, Bm, Cm, A,
                         interpret=(b == "pallas_interpret"))


def rwkv6_scan(r, k, v, w, u, *, backend=None):
    b = _resolve(backend)
    if b == "xla":
        return ref.rwkv6_scan_ref(r, k, v, w, u)
    return _rwkv_pallas(r, k, v, w, u, interpret=(b == "pallas_interpret"))


def moe_gmm(x, w, *, backend=None):
    b = _resolve(backend)
    if b == "xla":
        return ref.moe_gmm_ref(x, w)
    return _gmm_pallas(x, w, interpret=(b == "pallas_interpret"))


def aes_ctr(plaintext: jnp.ndarray, key_bytes: jnp.ndarray, *, nonce: int = 0,
            backend=None):
    b = _resolve(backend)
    if b == "xla":
        return ref.aes_ctr_ref(plaintext, key_bytes, nonce)
    rk = ref.aes_key_expand(key_bytes)
    return _aes_ctr_pallas(plaintext, rk, nonce=nonce,
                           interpret=(b == "pallas_interpret"))
