"""Architecture configuration dataclasses.

Every assigned architecture (and the paper's own AES benchmark function)
is expressed as an :class:`ArchConfig`.  The model zoo in
``repro.models`` consumes only this dataclass — nothing architecture
specific leaks into the layer code.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple


class ArchType(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    SSM = "ssm"          # attention-free (RWKV6)
    HYBRID = "hybrid"    # Mamba + attention interleave (Jamba)
    AUDIO = "audio"      # enc-dec transformer over audio-frame embeddings
    VLM = "vlm"          # decoder transformer over patch+text embeddings
    MICRO = "micro"      # non-LLM FaaS micro-function (paper's AES benchmark)


class BlockKind(str, enum.Enum):
    """Kind of a single residual block in the layer stack."""

    ATTN = "attn"        # attention + MLP (dense)
    ATTN_MOE = "attn_moe"
    MAMBA = "mamba"
    MAMBA_MOE = "mamba_moe"
    RWKV = "rwkv"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    # Router load-balancing auxiliary loss coefficient (Switch-style).
    aux_loss_coef: float = 0.01
    # Capacity factor used by the dispatch kernel / dropless fallback.
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64  # RWKV6 head size (d_model/head_size heads)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder split (seamless-m4t).  ``n_layers`` in ArchConfig is
    the *decoder* depth; the encoder consumes stub frame embeddings."""

    encoder_layers: int = 24
    # Max source positions (audio frames after the conv feature extractor).
    max_source_positions: int = 1500
    cross_attention: bool = True


@dataclasses.dataclass(frozen=True)
class FrontendStub:
    """Modality frontend carve-out: precomputed embeddings of this shape
    are produced by ``input_specs()`` instead of running a ViT/codec."""

    kind: str          # "audio_frames" | "image_patches"
    num_tokens: int    # frames or patches per item
    embed_dim: int


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: ArchType
    citation: str

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0              # 0 -> d_model // n_heads

    # Attention variants.
    sliding_window: Optional[int] = None   # SWA window (tokens), None = full
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    max_seq_len: int = 1 << 20

    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendStub] = None

    # HYBRID: one attention block every `attn_every` blocks (Jamba 1:7).
    attn_every: int = 0
    # MoE on every `moe_every`-th block (Jamba: every other block).
    moe_every: int = 1

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == ArchType.SSM

    @property
    def supports_long_context_natively(self) -> bool:
        """Sub-quadratic decode without any config override."""
        if self.arch_type in (ArchType.SSM, ArchType.HYBRID):
            return True
        return self.sliding_window is not None

    def block_kinds(self) -> Tuple[BlockKind, ...]:
        """The per-layer block pattern for the full stack."""
        kinds = []
        for i in range(self.n_layers):
            moe_here = self.moe is not None and (i % self.moe_every == (self.moe_every - 1))
            if self.arch_type == ArchType.SSM:
                kinds.append(BlockKind.RWKV)
            elif self.arch_type == ArchType.HYBRID:
                # Jamba: 1 attention layer per `attn_every` block group.
                is_attn = self.attn_every > 0 and (i % self.attn_every == (self.attn_every // 2))
                if is_attn:
                    kinds.append(BlockKind.ATTN_MOE if moe_here else BlockKind.ATTN)
                else:
                    kinds.append(BlockKind.MAMBA_MOE if moe_here else BlockKind.MAMBA)
            else:
                kinds.append(BlockKind.ATTN_MOE if moe_here else BlockKind.ATTN)
        return tuple(kinds)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        from repro.models.flops import param_count  # local import, avoids cycle
        return param_count(self)

    def active_param_count(self) -> int:
        from repro.models.flops import active_param_count
        return active_param_count(self)

    def validate(self) -> None:
        if self.arch_type == ArchType.MICRO:
            return
        assert self.n_layers > 0 and self.d_model > 0 and self.vocab_size > 0
        if self.arch_type != ArchType.SSM:
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0, "GQA requires n_heads % n_kv_heads == 0"
        if self.moe is not None:
            assert self.moe.top_k <= self.moe.num_experts


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 256,
            d_ff: int = 512, vocab_size: int = 512, max_experts: int = 4,
            seq_cap: int = 128) -> ArchConfig:
    """A smoke-test-sized variant of the same family (assignment: 2 layers,
    d_model<=512, <=4 experts)."""
    if cfg.arch_type == ArchType.MICRO:
        return cfg
    heads = max(1, min(cfg.n_heads, d_model // 64))
    kv = max(1, min(cfg.n_kv_heads, heads))
    while heads % kv:
        kv -= 1
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, num_experts=min(cfg.moe.num_experts, max_experts),
                                  top_k=min(cfg.moe.top_k, min(cfg.moe.num_experts, max_experts)))
    encdec = None
    if cfg.encdec is not None:
        encdec = dataclasses.replace(cfg.encdec, encoder_layers=n_layers,
                                     max_source_positions=32)
    frontend = None
    if cfg.frontend is not None:
        frontend = dataclasses.replace(cfg.frontend, num_tokens=min(cfg.frontend.num_tokens, 16),
                                       embed_dim=d_model)
    attn_every = cfg.attn_every
    if attn_every:
        attn_every = min(attn_every, n_layers)  # keep >=1 attn layer in hybrid smoke
    sw = cfg.sliding_window
    if sw is not None:
        sw = min(sw, seq_cap)
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n_layers=n_layers, d_model=d_model,
        n_heads=heads, n_kv_heads=kv, d_ff=d_ff, vocab_size=vocab_size,
        head_dim=0, moe=moe, encdec=encdec, frontend=frontend,
        attn_every=attn_every, sliding_window=sw, max_seq_len=seq_cap,
        mamba=cfg.mamba, rwkv=cfg.rwkv)
