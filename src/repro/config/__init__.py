from repro.config.arch import (ArchConfig, ArchType, BlockKind, EncDecConfig,
                               FrontendStub, MambaConfig, MoEConfig,
                               RWKVConfig, reduced)
from repro.config.registry import get_arch, list_archs, register
from repro.config.shapes import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                 PREFILL_32K, SHAPES, TRAIN_4K, InputShape,
                                 StepKind, get_shape)

__all__ = [
    "ArchConfig", "ArchType", "BlockKind", "EncDecConfig", "FrontendStub",
    "MambaConfig", "MoEConfig", "RWKVConfig", "reduced",
    "get_arch", "list_archs", "register",
    "ALL_SHAPES", "SHAPES", "InputShape", "StepKind", "get_shape",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
]
