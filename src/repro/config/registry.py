"""``--arch <id>`` registry.

Every module in ``repro.configs`` registers its :class:`ArchConfig` here at
import time; ``get_arch()`` lazily imports the package so CLI entry points
can simply call ``get_arch("mixtral-8x7b")``.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, List

from repro.config.arch import ArchConfig

_REGISTRY: Dict[str, Callable[[], ArchConfig]] = {}
_CACHE: Dict[str, ArchConfig] = {}


def register(name: str):
    def deco(fn: Callable[[], ArchConfig]):
        if name in _REGISTRY:
            raise ValueError(f"duplicate arch registration: {name}")
        _REGISTRY[name] = fn
        return fn
    return deco


def _ensure_loaded() -> None:
    importlib.import_module("repro.configs")


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _CACHE:
        if name not in _REGISTRY:
            raise KeyError(f"unknown arch {name!r}; choose from {list_archs()}")
        cfg = _REGISTRY[name]()
        cfg.validate()
        _CACHE[name] = cfg
    return _CACHE[name]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)
