"""Assigned input shapes and the step function each one lowers."""
from __future__ import annotations

import dataclasses
import enum
from typing import Tuple


class StepKind(str, enum.Enum):
    TRAIN = "train_step"        # fwd + bwd + optimizer update
    PREFILL = "prefill_step"    # full-sequence forward, writes KV cache
    DECODE = "serve_step"       # ONE new token against a KV cache of seq_len


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    step: StepKind


TRAIN_4K = InputShape("train_4k", 4_096, 256, StepKind.TRAIN)
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, StepKind.PREFILL)
DECODE_32K = InputShape("decode_32k", 32_768, 128, StepKind.DECODE)
LONG_500K = InputShape("long_500k", 524_288, 1, StepKind.DECODE)

ALL_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown input shape {name!r}; choose from {sorted(SHAPES)}") from None
