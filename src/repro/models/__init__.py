from repro.models import (attention, flops, frontends, layers, mamba, moe,
                          rwkv, scan_utils, transformer)

__all__ = ["attention", "flops", "frontends", "layers", "mamba", "moe",
           "rwkv", "scan_utils", "transformer"]
