"""Unified model definition for all assigned architectures.

A model is a stack of residual blocks whose kinds come from
``ArchConfig.block_kinds()``:

* homogeneous stacks (dense / MoE / RWKV) are ``lax.scan``-ed over layers
  with stacked parameters — compile cost is ONE block body;
* Jamba's 1:7 Mamba:attention interleave scans over *groups* of
  ``attn_every`` blocks (heterogeneous inside the group, stacked across
  groups);
* seamless-m4t adds a bidirectional encoder stack and cross-attention in
  every decoder block.

Three entry points per model, matching the assigned input shapes:
``forward`` (training, full sequence), ``prefill`` (writes KV/state
caches), ``decode_step`` (ONE token against the caches).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig, BlockKind
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import rwkv as rk
from repro.models.layers import (dense_init, dtype_of, embed_init, rms_norm,
                                 rms_norm_init, swiglu, swiglu_init)

SCAN_CHUNK = 64  # inner time-chunk for SSM scans


# ---------------------------------------------------------------------------
# Block init / apply


def _block_init(key, kind: BlockKind, cfg: ArchConfig, dtype,
                cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": rms_norm_init(d, dtype)}
    if kind in (BlockKind.ATTN, BlockKind.ATTN_MOE):
        p["attn"] = attn.attn_init(ks[0], cfg, dtype)
    elif kind in (BlockKind.MAMBA, BlockKind.MAMBA_MOE):
        p["mamba"] = mb.mamba_init(ks[0], cfg, dtype)
    elif kind == BlockKind.RWKV:
        p["rwkv"] = rk.rwkv_init(ks[0], cfg, dtype)
        p["ln2"] = rms_norm_init(d, dtype)
        return p
    if cross:
        p["ln_cross"] = rms_norm_init(d, dtype)
        p["cross"] = attn.attn_init(ks[2], cfg, dtype)
    p["ln2"] = rms_norm_init(d, dtype)
    if kind in (BlockKind.ATTN_MOE, BlockKind.MAMBA_MOE):
        p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = swiglu_init(ks[1], d, cfg.d_ff, dtype)
    return p


@dataclasses.dataclass
class Ctx:
    """Per-call context threaded through block application."""
    mode: str                      # "full" | "prefill" | "decode"
    positions: Optional[jnp.ndarray] = None   # (B,S) for full/prefill
    pos: Optional[jnp.ndarray] = None         # scalar for decode
    causal: bool = True
    moe_mode: str = "capacity"
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None
    cross_mask: Optional[jnp.ndarray] = None
    act_sharding: Any = None       # NamedSharding constraint between blocks
    unroll: bool = False           # unroll the layer scan (roofline probes)
    attn_impl: str = "dense"       # "dense" | "chunked" (flash-style XLA)
    cache_update: str = "dus"      # "dus" | "select" (SPMD-friendly)
    mixed_precision: bool = False  # bf16 dots w/ f32 accum (MXU-style)
    moe_dispatch_sharding: Any = None  # NamedSharding for (E,C,d) dispatch
    moe_local_groups: int = 0      # per-shard local dispatch group count
    moe_group_sharding: Any = None # shardings for the grouped dispatch


def _apply_block(kind: BlockKind, p: dict, x: jnp.ndarray, cfg: ArchConfig,
                 ctx: Ctx, cache: Optional[dict]) -> Tuple[jnp.ndarray, Optional[dict], jnp.ndarray]:
    """Returns (x, new_cache, moe_aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[dict] = None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)

    if kind == BlockKind.RWKV:
        if ctx.mode == "full":
            y, _ = rk.rwkv_time_mix(p["rwkv"], h, cfg, None, SCAN_CHUNK)
            x = x + y
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            y2, _ = rk.rwkv_channel_mix(p["rwkv"], h2, None)
            return x + y2, None, aux
        tm_state = None if cache is None else {"wkv": cache["wkv"], "shift_tm": cache["shift_tm"]}
        y, tm_new = rk.rwkv_time_mix(p["rwkv"], h, cfg, tm_state, SCAN_CHUNK)
        x = x + y
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        cm_state = None if cache is None else cache["shift_cm"]
        y2, cm_new = rk.rwkv_channel_mix(p["rwkv"], h2, cm_state)
        new_cache = {"wkv": tm_new["wkv"], "shift_tm": tm_new["shift_tm"], "shift_cm": cm_new}
        return x + y2, new_cache, aux

    # --- sequence-mix sublayer (attention or mamba) ---
    if kind in (BlockKind.ATTN, BlockKind.ATTN_MOE):
        if ctx.mode == "full":
            if ctx.causal:
                y = attn.attention_forward(p["attn"], h, cfg, ctx.positions,
                                           impl=ctx.attn_impl)
            else:  # encoder: bidirectional
                q, k, v = attn._project_qkv(p["attn"], h, cfg, ctx.positions)
                o = attn.gqa_attend(q, k, v, None)
                y = o.reshape(h.shape[0], h.shape[1], -1) @ p["attn"]["wo"]
        elif ctx.mode == "prefill":
            y, new_cache = attn.prefill_into_cache(p["attn"], h, cfg,
                                                   ctx.positions, cache,
                                                   impl=ctx.attn_impl)
        else:
            y, new_cache = attn.decode_step_attention(p["attn"], h, cfg,
                                                      ctx.pos, cache,
                                                      ctx.cache_update,
                                                      ctx.mixed_precision)
    else:  # mamba
        if ctx.mode == "full":
            y, _ = mb.mamba_forward(p["mamba"], h, cfg, None, SCAN_CHUNK)
        elif ctx.mode == "prefill":
            y, new_cache = mb.mamba_forward(p["mamba"], h, cfg, cache, SCAN_CHUNK)
        else:
            y, new_cache = mb.mamba_decode_step(p["mamba"], h, cfg, cache)
    x = x + y

    # --- cross-attention (enc-dec decoder) ---
    if "cross" in p and ctx.cross_kv is not None:
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        B, S, _ = hc.shape
        hd = cfg.resolved_head_dim
        q = (hc @ p["cross"]["wq"]).reshape(B, S, cfg.n_heads, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["cross"]["q_norm"], cfg.norm_eps)
        ck, cv = ctx.cross_kv
        m = None if ctx.cross_mask is None else jnp.broadcast_to(
            ctx.cross_mask[:, None, :], (B, S, ck.shape[1]))
        o = attn.gqa_attend(q, ck, cv, m)
        x = x + o.reshape(B, S, -1) @ p["cross"]["wo"]

    # --- channel-mix sublayer ---
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y2, aux = moe_mod.moe_apply(p["moe"], h2, cfg, ctx.moe_mode,
                                    dispatch_sharding=ctx.moe_dispatch_sharding,
                                    local_groups=ctx.moe_local_groups,
                                    group_sharding=ctx.moe_group_sharding)
    else:
        y2 = swiglu(p["mlp"], h2)
    x = x + y2
    if ctx.act_sharding is not None:
        x = jax.lax.with_sharding_constraint(x, ctx.act_sharding)
    return x, new_cache, aux


def _fresh_mamba_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    return mb.mamba_init_state(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# Stack plans


def _stack_plan(cfg: ArchConfig):
    """Group the layer pattern into scannable segments.

    Returns a list of (kinds_in_group: tuple, n_groups: int).  Homogeneous
    stacks give [((kind,), L)]; Jamba gives [((k0..k7), L//8)].
    """
    kinds = cfg.block_kinds()
    L = len(kinds)
    if len(set(kinds)) == 1:
        return [((kinds[0],), L)]
    # find smallest period p dividing L such that the pattern repeats
    for p in range(1, L + 1):
        if L % p == 0 and all(kinds[i] == kinds[i % p] for i in range(L)):
            return [(tuple(kinds[:p]), L // p)]
    return [(tuple(kinds), 1)]  # fully heterogeneous fallback


def _init_group(key, kinds, n_groups: int, cfg: ArchConfig, dtype, cross: bool):
    """Stacked params: tuple over in-group position, stacked over groups."""
    out = []
    for i, kind in enumerate(kinds):
        keys = jax.random.split(jax.random.fold_in(key, i), n_groups)
        stacked = jax.vmap(lambda k: _block_init(k, kind, cfg, dtype, cross))(keys)
        out.append(stacked)
    return tuple(out)


def _run_stack(params_groups, kinds, x: jnp.ndarray, cfg: ArchConfig, ctx: Ctx,
               caches, remat: bool = False):
    """Scan x through (n_groups x kinds) blocks.

    caches: tuple (per in-group position) of stacked per-group caches, or None.
    Returns (x, new_caches, total_aux).
    """
    has_cache = caches is not None

    def group_body(carry, xs):
        x, aux = carry
        p_tuple = xs[0]
        c_tuple = xs[1] if has_cache else (None,) * len(kinds)
        new_caches = []
        for kind, p, c in zip(kinds, p_tuple, c_tuple):
            x, nc, a = _apply_block(kind, p, x, cfg, ctx, c)
            aux = aux + a
            new_caches.append(nc if nc is not None else (c if c is not None else 0))
        ys = tuple(new_caches) if has_cache else 0
        return (x, aux), ys

    body = group_body
    if remat:
        body = jax.checkpoint(group_body, prevent_cse=False)

    xs = (params_groups, caches) if has_cache else (params_groups,)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                                unroll=True if ctx.unroll else 1)
    return x, (ys if has_cache else None), aux


# ---------------------------------------------------------------------------
# Model


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = dtype_of(cfg)
    k_e, k_b, k_h, k_enc, k_f = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": embed_init(k_e, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_h, cfg.d_model, cfg.vocab_size, dtype)
    cross = cfg.encdec is not None and cfg.encdec.cross_attention
    (kinds, n_groups), = _stack_plan(cfg)
    params["blocks"] = _init_group(k_b, kinds, n_groups, cfg, dtype, cross)
    if cfg.encdec is not None:
        enc_cfg = dataclasses.replace(cfg, sliding_window=None)
        keys = jax.random.split(k_enc, cfg.encdec.encoder_layers)
        params["enc_blocks"] = (jax.vmap(
            lambda k: _block_init(k, BlockKind.ATTN, enc_cfg, dtype, False))(keys),)
        params["enc_norm"] = rms_norm_init(cfg.d_model, dtype)
    if cfg.frontend is not None and cfg.frontend.embed_dim != cfg.d_model:
        params["frontend_proj"] = dense_init(k_f, cfg.frontend.embed_dim, cfg.d_model, dtype)
    return params


def _embed(params, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["embed"][tokens]


def _unembed(params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def _encode(params, cfg: ArchConfig, enc_embeds: jnp.ndarray,
            enc_mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Bidirectional encoder over stub frame embeddings."""
    x = enc_embeds
    if "frontend_proj" in params:
        x = x @ params["frontend_proj"]
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    ctx = Ctx(mode="full", positions=positions, causal=False)
    enc_cfg = dataclasses.replace(cfg, sliding_window=None)
    x, _, _ = _run_stack(params["enc_blocks"], (BlockKind.ATTN,), x, enc_cfg, ctx, None)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv_all_layers(params, cfg: ArchConfig, enc_out: jnp.ndarray):
    """Project encoder output into per-layer cross K/V (stacked over groups)."""
    (kinds, n_groups), = _stack_plan(cfg)
    out = []
    for i, kind in enumerate(kinds):
        p_stack = params["blocks"][i]
        kv = jax.vmap(lambda p: attn.project_kv_for_cross(p, enc_out, cfg))(p_stack["cross"])
        out.append(kv)  # (k,v) each (n_groups, B, T, Hkv, hd)
    return tuple(out)


def model_inputs_to_embeds(params, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    """Token and/or stub-frontend embeddings -> (B, S, d)."""
    if "embeds" in batch:
        x = batch["embeds"]
        if "frontend_proj" in params:
            x = x @ params["frontend_proj"]
        return x
    return _embed(params, cfg, batch["tokens"])


def forward(params: dict, cfg: ArchConfig, batch: dict,
            moe_mode: str = "capacity", remat: bool = False,
            act_sharding: Any = None, unroll: bool = False,
            attn_impl: str = "dense",
            moe_dispatch_sharding: Any = None, moe_local_groups: int = 0,
            moe_group_sharding: Any = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward: full sequence -> (logits (B,S,V), moe_aux)."""
    (kinds, n_groups), = _stack_plan(cfg)
    enc_out = None
    if cfg.encdec is not None:
        enc_out = _encode(params, cfg, batch["enc_embeds"], batch.get("enc_mask"))
    x = model_inputs_to_embeds(params, cfg, batch)
    B, S, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ctx = Ctx(mode="full", positions=positions, moe_mode=moe_mode,
              act_sharding=act_sharding, unroll=unroll, attn_impl=attn_impl,
              moe_dispatch_sharding=moe_dispatch_sharding,
              moe_local_groups=moe_local_groups,
              moe_group_sharding=moe_group_sharding)

    if cfg.encdec is not None:
        # cross-attn K/V precomputed per layer and fed as scan xs
        ckv = _cross_kv_all_layers(params, cfg, enc_out)
        x, _, aux = _run_stack_cross(params["blocks"], kinds, x, cfg, ctx, ckv,
                                     batch.get("enc_mask"), remat)
    else:
        x, _, aux = _run_stack(params["blocks"], kinds, x, cfg, ctx, None, remat)
    return _unembed(params, cfg, x), aux


def _run_stack_cross(params_groups, kinds, x, cfg, ctx: Ctx, ckv, enc_mask,
                     remat: bool = False, caches=None):
    """Like _run_stack but feeds per-layer cross K/V as extra scan inputs."""
    has_cache = caches is not None

    def group_body(carry, xs):
        x, aux = carry
        p_tuple, kv_tuple = xs[0], xs[1]
        c_tuple = xs[2] if has_cache else (None,) * len(kinds)
        new_caches = []
        for kind, p, kv, c in zip(kinds, p_tuple, kv_tuple, c_tuple):
            lctx = dataclasses.replace(ctx, cross_kv=kv, cross_mask=enc_mask)
            x, nc, a = _apply_block(kind, p, x, cfg, lctx, c)
            aux = aux + a
            new_caches.append(nc if nc is not None else (c if c is not None else 0))
        ys = tuple(new_caches) if has_cache else 0
        return (x, aux), ys

    body = jax.checkpoint(group_body, prevent_cse=False) if remat else group_body
    xs = (params_groups, ckv, caches) if has_cache else (params_groups, ckv)
    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                                unroll=True if ctx.unroll else 1)
    return x, (ys if has_cache else None), aux


# ---------------------------------------------------------------------------
# Caches


def init_caches(params, cfg: ArchConfig, batch: int, seq_len: int):
    """Stacked per-group caches matching the stack plan (+ cross-KV slots
    for enc-dec, filled at prefill)."""
    dtype = dtype_of(cfg)
    (kinds, n_groups), = _stack_plan(cfg)

    def one(kind):
        if kind in (BlockKind.ATTN, BlockKind.ATTN_MOE):
            return attn.init_kv_cache(cfg, batch, seq_len, dtype)
        if kind in (BlockKind.MAMBA, BlockKind.MAMBA_MOE):
            return mb.mamba_init_state(cfg, batch, dtype)
        return rk.rwkv_init_state(cfg, batch, dtype)

    caches = tuple(
        jax.tree_util.tree_map(lambda l: jnp.stack([l] * n_groups), one(kind))
        for kind in kinds)
    return caches


def prefill(params: dict, cfg: ArchConfig, batch: dict, seq_len: int,
            moe_mode: str = "capacity", act_sharding: Any = None,
            unroll: bool = False, attn_impl: str = "dense",
            moe_dispatch_sharding: Any = None):
    """Run the prompt, returning (last-token logits, caches dict)."""
    (kinds, n_groups), = _stack_plan(cfg)
    x = model_inputs_to_embeds(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    ctx = Ctx(mode="prefill", positions=positions, moe_mode=moe_mode,
              act_sharding=act_sharding, unroll=unroll, attn_impl=attn_impl,
              moe_dispatch_sharding=moe_dispatch_sharding)
    caches = init_caches(params, cfg, B, seq_len)
    extra = {}
    if cfg.encdec is not None:
        enc_out = _encode(params, cfg, batch["enc_embeds"], batch.get("enc_mask"))
        ckv = _cross_kv_all_layers(params, cfg, enc_out)
        x, caches, _ = _run_stack_cross(params["blocks"], kinds, x, cfg, ctx, ckv,
                                        batch.get("enc_mask"), False, caches)
        extra["cross_kv"] = ckv
        if batch.get("enc_mask") is not None:
            extra["enc_mask"] = batch["enc_mask"]
    else:
        x, caches, _ = _run_stack(params["blocks"], kinds, x, cfg, ctx, caches)
    logits = _unembed(params, cfg, x[:, -1:])
    return logits, {"layers": caches, **extra}


def decode_step(params: dict, cfg: ArchConfig, tokens: jnp.ndarray,
                pos: jnp.ndarray, caches: dict, moe_mode: str = "capacity",
                act_sharding: Any = None, unroll: bool = False,
                cache_update: str = "dus", mixed_precision: bool = False):
    """ONE-token decode. tokens: (B,1) int32; pos: scalar absolute position."""
    (kinds, n_groups), = _stack_plan(cfg)
    x = _embed(params, cfg, tokens)
    ctx = Ctx(mode="decode", pos=pos, moe_mode=moe_mode,
              act_sharding=act_sharding, unroll=unroll,
              cache_update=cache_update, mixed_precision=mixed_precision)
    if cfg.encdec is not None and "cross_kv" in caches:
        x, layer_caches, _ = _run_stack_cross(
            params["blocks"], kinds, x, cfg, ctx, caches["cross_kv"],
            caches.get("enc_mask"), False, caches["layers"])
        new = dict(caches)
        new["layers"] = layer_caches
    else:
        x, layer_caches, _ = _run_stack(params["blocks"], kinds, x, cfg, ctx,
                                        caches["layers"])
        new = {"layers": layer_caches}
    logits = _unembed(params, cfg, x)
    return logits, new
