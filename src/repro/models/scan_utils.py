"""Chunked diagonal-decay linear recurrences.

Both Mamba's selective scan and RWKV6's wkv recurrence are instances of

    h_t = a_t * h_{t-1} + b_t          (a broadcast-diagonal over state)

which is associative.  The full state sequence is O(T * state) memory —
prohibitive for matrix-valued states (RWKV: hd*hd per head; Mamba:
d_inner*d_state) — and even a_t/b_t themselves are outer products of the
same size.  ``linear_scan_emit`` therefore runs an outer ``lax.scan`` over
chunks and, *inside* each chunk, (1) builds a/b from factored inputs via
``make_ab``, (2) runs an ``associative_scan``, and (3) immediately reduces
states to outputs via ``emit_fn``.  Live memory is O(chunk * state).

The outer scan's trip count is invisible to XLA ``cost_analysis``; the
roofline module corrects it via cost components
(repro.analysis.roofline).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def _combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def linear_scan_emit(inputs, h0: jnp.ndarray, make_ab: Callable,
                     emit_fn: Callable, chunk: int = 64
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t h_{t-1} + b_t, chunk-factored.

    inputs: pytree with leading time axis T (small per-step tensors).
    make_ab(chunk_inputs) -> (a, b) each (c, *state)  — built per chunk.
    emit_fn(h_prev (c,*state), h_post (c,*state), chunk_inputs) -> y (c, ...).
    Returns (y: (T, ...), h_T).
    """
    leaves = jax.tree_util.tree_leaves(inputs)
    T = leaves[0].shape[0]

    def chunk_apply(h, cin):
        a, b = make_ab(cin)
        aa, bb = jax.lax.associative_scan(_combine, (a, b), axis=0)
        hs = aa * h[None] + bb                       # states after each step
        h_prev = jnp.concatenate([h[None], hs[:-1]], axis=0)
        return hs[-1], emit_fn(h_prev, hs, cin)

    if T <= chunk:
        h_last, y = chunk_apply(h0, inputs)
        return y, h_last
    assert T % chunk == 0, f"T={T} not divisible by chunk={chunk}"
    n = T // chunk

    def reshape_c(x):
        return x.reshape((n, chunk) + x.shape[1:])

    xs = jax.tree_util.tree_map(reshape_c, inputs)
    h_last, ys = jax.lax.scan(lambda h, c: chunk_apply(h, c), h0, xs)
    y = ys.reshape((T,) + ys.shape[2:])
    return y, h_last


def linear_scan_ref(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray):
    """Sequential oracle for tests: returns all post-update states."""
    def body(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    h_last, hs = jax.lax.scan(body, h0, (a, b))
    return hs, h_last


def scan_chunk_count(T: int, chunk: int = 64) -> int:
    """Number of outer-scan iterations ``linear_scan_emit`` performs."""
    return 1 if T <= chunk else T // chunk
