"""GQA attention with RoPE, optional qk-norm and sliding-window, plus a
single-token decode path against a (ring-buffered) KV cache.

Reference path is pure jnp (the oracle / dry-run path, lowered by XLA).
On real TPU hardware the Pallas kernels in :mod:`repro.kernels` are
selected via ``backend="pallas"``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models.layers import apply_rope, causal_mask, dense_init, rms_norm

NEG_INF = -1e30


def attn_init(key, cfg: ArchConfig, dtype) -> dict:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(k2, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                 positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ params["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               mask: Optional[jnp.ndarray],
               mixed_precision: bool = False) -> jnp.ndarray:
    """q: (B,S,Hq,hd); k,v: (B,T,Hkv,hd); mask: (S,T) or (B,S,T) bool.

    ``mixed_precision``: feed bf16 operands straight into the dot with an
    fp32 accumulator (``preferred_element_type``) instead of materialising
    fp32 COPIES of K/V — this is exactly what the TPU MXU does natively,
    and removes the dominant ``convert`` HBM traffic the dry-run profile
    shows on the decode path (§Perf iteration 'mixed_prec').
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, hd)
    if mixed_precision:
        scores = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                            preferred_element_type=jnp.float32) / (hd ** 0.5)
    else:
        scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) / (hd ** 0.5)
    if mask is not None:
        m = mask if mask.ndim == 3 else mask[None]
        scores = jnp.where(m[:, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    if mixed_precision:
        out = jnp.einsum("bkgst,btkd->bskgd", w.astype(q.dtype), v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, hd).astype(q.dtype)


def chunked_gqa_attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                       causal: bool = True, window: Optional[int] = None,
                       q_chunk: int = 512) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure XLA: scan over query
    chunks so the (S, T) score matrix is never materialised — the HBM
    traffic drops from O(S*T*H) to O(S*H*d + chunk*T*H).  This is the
    XLA twin of the Pallas flash kernel (used where pallas can't lower),
    and the §Perf "memory-term" optimization for prefill/train.

    q: (B,S,Hq,hd); k/v: (B,T,Hkv,hd) -> (B,S,Hq,hd).
    """
    B, S, Hq, hd = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    cq = min(q_chunk, S)
    pad = (-S) % cq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (S + pad) // cq
    qs = q.reshape(B, n, cq, Hkv, G, hd)
    scale = hd ** -0.5
    k_pos = jnp.arange(T)

    def one_chunk(_, qi_i):
        qi, i = qi_i                                   # (B,cq,Hkv,G,hd), idx
        # bf16 dots with fp32 accumulation (MXU-native) — no fp32 K/V copies
        s = jnp.einsum("bskgd,btkd->bkgst", qi, k,
                       preferred_element_type=jnp.float32) * scale
        q_pos = i * cq + jnp.arange(cq) + (T - S)
        m = jnp.ones((cq, T), bool)
        if causal:
            m &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            m &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(m[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), v,
                       preferred_element_type=jnp.float32)
        return None, o

    _, outs = jax.lax.scan(one_chunk, None,
                           (jnp.moveaxis(qs, 1, 0), jnp.arange(n)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S + pad, Hq, hd)
    return out[:, :S].astype(q.dtype)


def attention_forward(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                      positions: jnp.ndarray,
                      kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                      kv_mask: Optional[jnp.ndarray] = None,
                      impl: str = "dense") -> jnp.ndarray:
    """Full-sequence self-attention (training / prefill).

    ``kv`` overrides the self-derived k/v (cross-attention for enc-dec);
    ``kv_mask``: (B, T) validity of the cross keys.
    ``impl``: "dense" (oracle; materialises scores) or "chunked"
    (flash-style, memory-optimal in XLA).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)
    if kv is not None:
        k, v = kv
        mask = None if kv_mask is None else jnp.broadcast_to(kv_mask[:, None, :], (B, S, k.shape[1]))
        out = gqa_attend(q, k, v, mask)
    elif impl == "chunked":
        out = chunked_gqa_attend(q, k, v, causal=True,
                                 window=cfg.sliding_window)
    else:
        mask = causal_mask(S, S, window=cfg.sliding_window)
        out = gqa_attend(q, k, v, mask)
    return out.reshape(B, S, -1) @ params["wo"]


def project_kv_for_cross(params: dict, enc: jnp.ndarray, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Project encoder output once into cross-attention K/V (no RoPE)."""
    B, T, _ = enc.shape
    hd = cfg.resolved_head_dim
    k = (enc @ params["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
    v = (enc @ params["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# KV cache (per layer)


def kv_cache_capacity(cfg: ArchConfig, seq_len: int) -> int:
    """SWA architectures use a ring buffer of window size."""
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_kv_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype) -> dict:
    cap = kv_cache_capacity(cfg, seq_len)
    hd = cfg.resolved_head_dim
    shape = (batch, cap, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill_into_cache(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                       positions: jnp.ndarray, cache: dict,
                       impl: str = "dense") -> Tuple[jnp.ndarray, dict]:
    """Self-attention over the prompt AND write the (ring) cache."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)
    if impl == "chunked":
        out = chunked_gqa_attend(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        mask = causal_mask(S, S, window=cfg.sliding_window)
        out = gqa_attend(q, k, v, mask)
    cap = cache["k"].shape[1]
    if cap >= S:
        cache = {"k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
                 "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))}
    else:
        # ring: keep the last `cap` tokens, rolled so slot j holds pos p≡j (mod cap)
        k_tail, v_tail = k[:, S - cap:], v[:, S - cap:]
        shift = (S - cap) % cap
        cache = {"k": jnp.roll(k_tail, shift, axis=1), "v": jnp.roll(v_tail, shift, axis=1)}
    y = out.reshape(B, S, -1) @ params["wo"]
    return y, cache


def decode_step_attention(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                          pos: jnp.ndarray, cache: dict,
                          cache_update: str = "dus",
                          mixed_precision: bool = False) -> Tuple[jnp.ndarray, dict]:
    """One-token decode: x (B, 1, d); pos scalar int32 (absolute position of
    the new token).  Writes k/v into the cache (ring slot for SWA) and
    attends over all valid cache entries.

    ``cache_update``: "dus" (dynamic_update_slice — natural, but SPMD must
    involuntarily REPLICATE a cache whose sequence dim is sharded, because
    the slot index is dynamic) or "select" (iota==slot masked select —
    elementwise, so the sharded layout is preserved; the §Perf fix).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    cap = cache["k"].shape[1]
    slot = pos % cap
    if cache_update == "select":
        sel = (jnp.arange(cap) == slot)[None, :, None, None]
        ck = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    # Absolute position held by slot j after the write.
    j = jnp.arange(cap)
    abs_pos = pos - ((pos - j) % cap)
    valid = abs_pos >= 0
    if cfg.sliding_window is not None:
        valid &= abs_pos > pos - cfg.sliding_window
    out = gqa_attend(q, ck, cv, jnp.broadcast_to(valid[None, None, :], (B, 1, cap)),
                     mixed_precision=mixed_precision)
    y = out.reshape(B, 1, -1) @ params["wo"]
    return y, {"k": ck, "v": cv}
