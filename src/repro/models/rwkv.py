"""RWKV-6 "Finch" block — attention-free linear recurrence with
data-dependent per-channel decay [arXiv:2404.05892].

Per head (size hd) with receptance r, key k, value v, decay w, bonus u:

    o_t = r_t^T (S_{t-1} + diag(u ⊙ k_t) v_t ... )      (bonus on current)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

where w_t = exp(-exp(wlog_t)) is data-dependent (LoRA on the shifted
input), matching the Finch formulation.  The sequence path reuses the
chunked diagonal linear scan over the (hd x hd) state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models.layers import dense_init
from repro.models.scan_utils import linear_scan_emit

LORA_RANK = 32


def _heads(cfg: ArchConfig) -> Tuple[int, int]:
    hd = cfg.rwkv.head_size
    return cfg.d_model // hd, hd


def rwkv_init(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    H, hd = _heads(cfg)
    ks = jax.random.split(key, 12)
    p = {
        # token-shift interpolation factors (static part of ddlerp)
        "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype), "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "wr": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "wg": dense_init(ks[3], d, d, dtype),
        "wo": dense_init(ks[4], d, d, dtype),
        # data-dependent decay LoRA: d -> rank -> d
        "wdec_a": dense_init(ks[5], d, LORA_RANK, dtype),
        "wdec_b": dense_init(ks[6], LORA_RANK, d, dtype),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "bonus_u": (jax.random.normal(ks[7], (H, hd), jnp.float32) * 0.1),
        "ln_x": jnp.ones((d,), dtype),
        # channel-mix (RWKV FFN)
        "cm_mu_k": jnp.full((d,), 0.5, dtype), "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_wk": dense_init(ks[8], d, cfg.d_ff, dtype),
        "cm_wv": dense_init(ks[9], cfg.d_ff, d, dtype),
        "cm_wr": dense_init(ks[10], d, d, dtype),
    }
    return p


def _token_shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x: (B,S,d) -> x_{t-1}; prev (B,1,d) is the last token of the previous
    segment (zeros at sequence start)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def _wkv_terms(params: dict, x: jnp.ndarray, xs: jnp.ndarray, cfg: ArchConfig):
    """Produce r,k,v,g,w for the wkv recurrence. x,xs: (B,S,d)."""
    B, S, d = x.shape
    H, hd = _heads(cfg)
    r = _mix(x, xs, params["mu_r"]) @ params["wr"]
    k = _mix(x, xs, params["mu_k"]) @ params["wk"]
    v = _mix(x, xs, params["mu_v"]) @ params["wv"]
    g = jax.nn.silu(_mix(x, xs, params["mu_g"]) @ params["wg"])
    wx = _mix(x, xs, params["mu_w"])
    wlog = params["decay_base"] + (jnp.tanh(wx @ params["wdec_a"]) @ params["wdec_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wlog))                                   # (B,S,d) in (0,1)
    shp = (B, S, H, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp), g,
            w.reshape(shp))


def rwkv_time_mix(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                  state: Optional[dict] = None, chunk: int = 128
                  ) -> Tuple[jnp.ndarray, dict]:
    """x: (B,S,d) -> (y, new_state)."""
    B, S, d = x.shape
    H, hd = _heads(cfg)
    prev_x = None if state is None else state["shift_tm"]
    xs = _token_shift(x, prev_x)
    r, k, v, g, w = _wkv_terms(params, x, xs, cfg)
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    S0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None else state["wkv"])
    u = params["bonus_u"]
    def t0(t):
        return jnp.moveaxis(t, 1, 0)                              # time-major
    inputs = (t0(rf), t0(kf), t0(vf), t0(wf))

    def make_ab(cin):
        # state (B,H,hd,hd); a_t = w_t broadcast on the k-index axis;
        # b_t = k_t v_t^T — outer products only formed per chunk.
        _, kc, vc, wc = cin
        b = kc[..., :, None] * vc[..., None, :]                   # (c,B,H,hd,hd)
        a = jnp.broadcast_to(wc[..., :, None], b.shape)
        return a, b

    def emit(S_prev, S_post, cin):
        rc, kc, vc, _ = cin                                       # (c,B,H,hd)
        kv = kc[..., :, None] * vc[..., None, :]                  # (c,B,H,hd,hd)
        eff = S_prev + u[None, None, :, :, None] * kv
        return jnp.einsum("cbhij,cbhi->cbhj", eff, rc)            # (c,B,H,hd)

    o, S_last = linear_scan_emit(inputs, S0, make_ab, emit, chunk=chunk)
    o = jnp.moveaxis(o, 0, 1).reshape(B, S, d)                    # (B,S,d)
    # group-norm-ish: rms over head dim then learned scale
    o = o / (jnp.sqrt(jnp.mean(jnp.square(o.reshape(B, S, H, hd)), axis=-1, keepdims=True) + 1e-5)
             ).reshape(B, S, H, 1).repeat(hd, -1).reshape(B, S, d)
    y = ((o * params["ln_x"].astype(jnp.float32)).astype(x.dtype) * g) @ params["wo"]
    new_state = {"wkv": S_last, "shift_tm": x[:, -1:]}
    return y, new_state


def rwkv_channel_mix(params: dict, x: jnp.ndarray,
                     state: Optional[dict] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    prev = None if state is None else state
    xs = _token_shift(x, prev)
    k = _mix(x, xs, params["cm_mu_k"]) @ params["cm_wk"]
    r = jax.nn.sigmoid(_mix(x, xs, params["cm_mu_r"]) @ params["cm_wr"])
    v = (jnp.square(jax.nn.relu(k))) @ params["cm_wv"]
    return r * v, x[:, -1:]


def rwkv_init_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    H, hd = _heads(cfg)
    d = cfg.d_model
    return {
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, 1, d), dtype),
        "shift_cm": jnp.zeros((batch, 1, d), dtype),
    }
