"""Mamba (S6) block — selective state-space model [Jamba, arXiv:2403.19887].

    h_t = exp(dt_t ⊙ A) h_{t-1} + dt_t ⊙ (B_t x_t)
    y_t = C_t · h_t + D ⊙ x_t

with input-dependent dt, B, C.  Sequence path uses the chunked diagonal
linear scan; decode keeps an O(1) recurrent state (h, conv window).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models.layers import dense_init
from repro.models.scan_utils import linear_scan_emit


def _dt_rank(cfg: ArchConfig) -> int:
    return math.ceil(cfg.d_model / 16)


def mamba_init(key, cfg: ArchConfig, dtype) -> dict:
    mc = cfg.mamba
    d, di, ds = cfg.d_model, mc.d_inner(cfg.d_model), mc.d_state
    dtr = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    A = -jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (mc.d_conv, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj_w": dense_init(ks[3], dtr, di, dtype),
        "dt_proj_b": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(-A),                         # (di, ds) fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype),
    }


def _ssm_terms(params: dict, xs: jnp.ndarray, cfg: ArchConfig):
    """xs: (B,S,di) post-conv activations -> factored scan terms:
    dt (B,S,di), dtx (B,S,di), Bm/Cm (B,S,ds).  The (di,ds) outer products
    are only formed per chunk inside the scan."""
    ds = cfg.mamba.d_state
    dtr = _dt_rank(cfg)
    proj = xs @ params["x_proj"]                                  # (B,S,dtr+2ds)
    dt_in, Bm, Cm = jnp.split(proj.astype(jnp.float32), [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj_w"].astype(jnp.float32)
                         + params["dt_proj_b"].astype(jnp.float32))  # (B,S,di)
    dtx = dt * xs.astype(jnp.float32)
    return dt, dtx, Bm, Cm


def _conv1d(params: dict, x: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B,S,di). state: (B, d_conv-1, di) history."""
    dc = params["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                        # (B, S+dc-1, di)
    out = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][i] for i in range(dc))
    new_state = xp[:, -(dc - 1):]
    return out + params["conv_b"], new_state


def mamba_forward(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                  state: Optional[dict] = None, chunk: int = 128
                  ) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence path. x: (B,S,d). Returns (y, final_state)."""
    B, S, _ = x.shape
    di = cfg.mamba.d_inner(cfg.d_model)
    ds = cfg.mamba.d_state
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xs, new_conv = _conv1d(params, xs, conv_state)
    xs = jax.nn.silu(xs)
    dt, dtx, Bm, Cm = _ssm_terms(params, xs, cfg)
    h0 = jnp.zeros((B, di, ds), jnp.float32) if state is None else state["h"]
    A = -jnp.exp(params["A_log"])                                 # (di,ds)
    def t0(t):
        return jnp.moveaxis(t, 1, 0)                              # time-major
    inputs = (t0(dt), t0(dtx), t0(Bm), t0(Cm))

    def make_ab(cin):
        dt_c, dtx_c, B_c, _ = cin
        a = jnp.exp(dt_c[..., None] * A)                          # (c,B,di,ds)
        b = dtx_c[..., None] * B_c[..., None, :]                  # (c,B,di,ds)
        return a, b

    def emit(h_prev, h_post, cin):
        # y_t = C_t · h_t  — reduce the state dim immediately (no O(S·state))
        return jnp.einsum("sbde,sbe->sbd", h_post, cin[3])

    y, h_last = linear_scan_emit(inputs, h0, make_ab, emit, chunk=chunk)
    y = jnp.moveaxis(y, 0, 1)                                     # (B,S,di)
    y = y + xs.astype(jnp.float32) * params["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    return y, {"h": h_last, "conv": new_conv.astype(x.dtype)}


def mamba_decode_step(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                      state: dict) -> Tuple[jnp.ndarray, dict]:
    """One-token recurrent step. x: (B,1,d)."""
    B = x.shape[0]
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, new_conv = _conv1d(params, xs, state["conv"])
    xs = jax.nn.silu(xs)
    dt, dtx, Bm, Cm = _ssm_terms(params, xs, cfg)                 # (B,1,...)
    A = -jnp.exp(params["A_log"])                                 # (di,ds)
    a = jnp.exp(dt[:, 0, :, None] * A)                            # (B,di,ds)
    b = dtx[:, 0, :, None] * Bm[:, 0, None, :]
    h = a * state["h"] + b                                        # (B,di,ds)
    y = jnp.einsum("bde,be->bd", h, Cm[:, 0])[:, None]            # (B,1,di)
    y = y + xs.astype(jnp.float32) * params["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"]
    return y, {"h": h, "conv": new_conv}


def mamba_init_state(cfg: ArchConfig, batch: int, dtype) -> dict:
    di = cfg.mamba.d_inner(cfg.d_model)
    return {
        "h": jnp.zeros((batch, di, cfg.mamba.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba.d_conv - 1, di), dtype),
    }
