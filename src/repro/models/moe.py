"""Mixture-of-Experts layer (top-k routing, SwiGLU experts).

Two execution paths:

* ``moe_dense_ref``  — computes every expert for every token and mixes by
  router weight.  O(E) compute; the smoke-test / property-test oracle.
* ``moe_capacity``   — GShard-style fixed-capacity dispatch implemented
  with scatter/gather (cheap, no O(T^2) dispatch einsum).  Tokens over
  capacity are dropped (weight renormalised); with a generous capacity
  factor it is numerically identical to the oracle.  Under pjit the expert
  dimension shards over the ``model``/``expert`` axis, giving expert
  parallelism; the baseline dry-run uses GSPMD's choice of collectives and
  §Perf iterates on it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models.layers import dense_init


def moe_init(key, cfg: ArchConfig, dtype) -> dict:
    E = cfg.moe.num_experts
    k_router, k1, k2, k3 = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.d_ff

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))(jax.random.split(k, E))

    return {
        "router": dense_init(k_router, d, E, jnp.float32),
        "w_gate": stack(k1, d, f),
        "w_up": stack(k2, d, f),
        "w_down": stack(k3, f, d),
    }


def _route(params: dict, x: jnp.ndarray, cfg: ArchConfig):
    """x: (T, d) -> (weights (T,k), idx (T,k), aux_loss scalar)."""
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    logits = (x.astype(jnp.float32) @ params["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)                        # (T,k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance aux loss.
    T = x.shape[0]
    hard = jnp.sum(jax.nn.one_hot(idx, E), axis=1)                # (T,E)
    frac_tokens = jnp.mean(hard, axis=0)                          # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs) * cfg.moe.aux_loss_coef
    return weights, idx, aux


def _expert_ffn(params: dict, xe: jnp.ndarray) -> jnp.ndarray:
    """xe: (E, C, d) -> (E, C, d); batched SwiGLU over the expert dim."""
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    return jnp.einsum("ecf,efd->ecd", gate * up, params["w_down"])


def moe_dense_ref(params: dict, x: jnp.ndarray, cfg: ArchConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle: run all experts on all tokens. x: (T, d)."""
    weights, idx, aux = _route(params, x, cfg)
    E = cfg.moe.num_experts
    xe = jnp.broadcast_to(x[None], (E,) + x.shape)                # (E,T,d)
    ye = _expert_ffn(params, xe)                                  # (E,T,d)
    gate = jnp.sum(jax.nn.one_hot(idx, E) * weights[..., None], axis=1)  # (T,E)
    y = jnp.einsum("te,etd->td", gate.astype(ye.dtype), ye)
    return y, aux


def moe_capacity(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                 capacity: int | None = None,
                 dispatch_sharding=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-capacity scatter/gather dispatch. x: (T, d).

    ``dispatch_sharding``: optional NamedSharding for the (E, C+1, d)
    dispatched/expert-output tensors.  Without it GSPMD tends to replicate
    the dispatch buffer across the data axis (the dominant collective in
    the MoE train dry-runs); constraining C over the data axis keeps the
    scatter local (§Perf iteration 'moe_shard').
    """
    T, d = x.shape
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    if capacity is None:
        capacity = max(1, int(cfg.moe.capacity_factor * k * T / E))
        if dispatch_sharding is not None:
            # make C+1 divide the mesh axes the constraint names (256 covers
            # any product of the 16x16 pod axes)
            capacity = -(-(capacity + 1) // 256) * 256 - 1
    weights, idx, aux = _route(params, x, cfg)

    flat_expert = idx.reshape(-1)                                 # (T*k,)
    flat_weight = weights.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), k)
    # Rank of each (token, slot) within its expert, in token order.
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)      # (T*k, E)
    rank = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1  # (T*k,)
    keep = rank < capacity
    safe_rank = jnp.where(keep, rank, capacity)                   # overflow -> scratch row

    # Dispatch: (E, capacity+1, d); the +1 row absorbs dropped tokens.
    dispatched = jnp.zeros((E, capacity + 1, d), x.dtype)
    dispatched = dispatched.at[flat_expert, safe_rank].set(x[flat_token])
    if dispatch_sharding is not None:
        dispatched = jax.lax.with_sharding_constraint(dispatched, dispatch_sharding)
    ye = _expert_ffn(params, dispatched[:, :capacity])            # (E, C, d)
    ye = jnp.concatenate([ye, jnp.zeros((E, 1, d), ye.dtype)], axis=1)
    if dispatch_sharding is not None:
        ye = jax.lax.with_sharding_constraint(ye, dispatch_sharding)
    # Combine.
    gathered = ye[flat_expert, safe_rank]                         # (T*k, d)
    gathered = gathered * (flat_weight * keep).astype(gathered.dtype)[:, None]
    y = jnp.sum(gathered.reshape(T, k, d), axis=1)
    return y, aux


def moe_capacity_grouped(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                         n_groups: int, capacity: int | None = None,
                         group_sharding=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """LOCAL dispatch: tokens are split into ``n_groups`` contiguous groups
    (aligned with the data shards), and routing/rank/dispatch/combine all
    carry the group dim — so the cumsum and gathers never cross shards.
    This is the per-shard dispatch every production MoE system uses; the
    global-cumsum variant above is the faithful GShard oracle.

    x: (T, d) with T % n_groups == 0.
    """
    T, d = x.shape
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    g = n_groups
    Tl = T // g
    if capacity is None:
        capacity = max(1, int(cfg.moe.capacity_factor * k * Tl / E))
        capacity = -(-(capacity + 1) // 16) * 16 - 1   # C+1 16-divisible
    xg = x.reshape(g, Tl, d)
    if group_sharding is not None:
        xg = jax.lax.with_sharding_constraint(xg, group_sharding["x"])
    weights, idx, aux = _route(params, xg.reshape(g * Tl, d), cfg)
    weights = weights.reshape(g, Tl, k)
    idx = idx.reshape(g, Tl, k)

    flat_expert = idx.reshape(g, Tl * k)
    flat_weight = weights.reshape(g, Tl * k)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)      # (g,Tl*k,E)
    rank = jnp.sum(jnp.cumsum(onehot, axis=1) * onehot, axis=-1) - 1
    keep = rank < capacity
    safe_rank = jnp.where(keep, rank, capacity)

    gi = jnp.arange(g)[:, None]
    tok = jnp.broadcast_to(jnp.repeat(jnp.arange(Tl), k)[None], (g, Tl * k))
    dispatched = jnp.zeros((g, E, capacity + 1, d), x.dtype)
    dispatched = dispatched.at[gi, flat_expert, safe_rank].set(xg[gi, tok])
    if group_sharding is not None:
        dispatched = jax.lax.with_sharding_constraint(dispatched,
                                                      group_sharding["dispatch"])
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", dispatched[:, :, :capacity],
                                  params["w_gate"]))
    up = jnp.einsum("gecd,edf->gecf", dispatched[:, :, :capacity], params["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", gate * up, params["w_down"])
    ye = jnp.concatenate([ye, jnp.zeros((g, E, 1, d), ye.dtype)], axis=2)
    if group_sharding is not None:
        ye = jax.lax.with_sharding_constraint(ye, group_sharding["dispatch"])
    gathered = ye[gi, flat_expert, safe_rank]                     # (g,Tl*k,d)
    gathered = gathered * (flat_weight * keep).astype(gathered.dtype)[..., None]
    y = jnp.sum(gathered.reshape(g, Tl, k, d), axis=2)
    return y.reshape(T, d), aux


def moe_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig,
              mode: str = "capacity", dispatch_sharding=None,
              local_groups: int = 0,
              group_sharding=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (B, S, d), aux loss."""
    B, S, d = x.shape
    flat = x.reshape(B * S, d)
    if mode == "dense":
        y, aux = moe_dense_ref(params, flat, cfg)
    elif local_groups > 1 and (B * S) % local_groups == 0:
        y, aux = moe_capacity_grouped(params, flat, cfg, local_groups,
                                      group_sharding=group_sharding)
    else:
        y, aux = moe_capacity(params, flat, cfg,
                              dispatch_sharding=dispatch_sharding)
    return y.reshape(B, S, d), aux
