"""Modality-frontend STUBS (the one sanctioned carve-out).

For [audio] and [vlm] architectures the assignment specifies the
transformer backbone only; the mel-spectrogram/conv feature extractor and
the ViT/SigLIP vision encoder are stubs.  ``input_specs()`` in
repro.launch.dryrun provides ShapeDtypeStruct stand-ins; here we provide
the matching *concrete* generators used by smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ArchConfig


def stub_frontend_embeddings(cfg: ArchConfig, key, batch: int,
                             num_tokens: int | None = None) -> jnp.ndarray:
    """Precomputed frame/patch embeddings of the right shape."""
    assert cfg.frontend is not None, f"{cfg.name} has no frontend stub"
    n = num_tokens or cfg.frontend.num_tokens
    x = jax.random.normal(key, (batch, n, cfg.frontend.embed_dim), jnp.float32)
    return x.astype(jnp.dtype(cfg.dtype))


def frontend_token_count(cfg: ArchConfig) -> int:
    return 0 if cfg.frontend is None else cfg.frontend.num_tokens
