"""Shared neural-net building blocks (pure-functional JAX).

Parameters are plain pytrees (nested dicts of jnp arrays); every function
takes ``(params, inputs, cfg)`` and returns arrays.  No framework objects —
this keeps everything trivially compatible with jit / scan / pjit.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import ArchConfig

# ---------------------------------------------------------------------------
# Initialisers


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def rms_norm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP


def swiglu_init(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]


# ---------------------------------------------------------------------------
# Masks


def causal_mask(q_len: int, kv_len: int, *, window: int | None = None,
                q_offset: int = 0) -> jnp.ndarray:
    """Boolean (q_len, kv_len) mask; True = attend.  ``q_offset`` is the
    absolute position of query 0 relative to kv position 0."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = k_pos <= q_pos
    if window is not None:
        mask &= k_pos > (q_pos - window)
    return mask


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def param_size(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))
