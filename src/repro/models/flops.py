"""Analytic parameter / FLOP accounting.

``param_count`` derives N from the *actual* parameter tree via
``jax.eval_shape`` (no allocation), so it can never drift from the code.
``model_flops`` implements the standard 6·N·D (train) / 2·N·D (inference)
estimates with MoE N_active, used for the roofline "useful compute" ratio.
"""
from __future__ import annotations

import functools
import math
from typing import Dict

import jax

from repro.config import ArchConfig, ArchType, InputShape, StepKind


@functools.lru_cache(maxsize=64)
def _param_shapes(cfg: ArchConfig):
    from repro.models import transformer
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: transformer.init_params(cfg, k), key)


def param_count(cfg: ArchConfig) -> int:
    if cfg.arch_type == ArchType.MICRO:
        return 0
    tree = _param_shapes(cfg)
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree_util.tree_leaves(tree))


def _expert_params_per_moe_layer(cfg: ArchConfig) -> int:
    # SwiGLU experts: 3 * d * d_ff each.
    return 3 * cfg.d_model * cfg.d_ff


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top_k of num_experts)."""
    if cfg.arch_type == ArchType.MICRO:
        return 0
    n = param_count(cfg)
    if cfg.moe is None:
        return n
    per_layer = _expert_params_per_moe_layer(cfg)
    n_moe_layers = sum(1 for k in cfg.block_kinds() if "moe" in k.value)
    inactive = per_layer * (cfg.moe.num_experts - cfg.moe.top_k) * n_moe_layers
    return n - inactive


def _nonembedding_active(cfg: ArchConfig) -> int:
    n = active_param_count(cfg)
    emb = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        emb *= 2
    return n - emb


def attention_flops(cfg: ArchConfig, seq_len: int, batch: int,
                    kv_len: int | None = None) -> int:
    """Score+PV matmul FLOPs for all attention layers (fwd)."""
    if cfg.arch_type == ArchType.MICRO or cfg.n_heads == 0:
        return 0
    kinds = cfg.block_kinds()
    n_attn = sum(1 for k in kinds if k.value.startswith("attn"))
    hd = cfg.resolved_head_dim
    kv = kv_len if kv_len is not None else seq_len
    if cfg.sliding_window is not None:
        kv = min(kv, cfg.sliding_window)
    # 2 matmuls (QK^T and PV), 2 flops per MAC; causal halves the prefill cost
    per_layer = 2 * 2 * batch * seq_len * kv * cfg.n_heads * hd
    if kv_len is None:
        per_layer //= 2
    return n_attn * per_layer


def model_flops(cfg: ArchConfig, shape: InputShape) -> Dict[str, float]:
    """MODEL_FLOPS per executed step (the roofline 'useful compute')."""
    if cfg.arch_type == ArchType.MICRO:
        return {"model_flops": 0.0, "tokens": 0.0}
    N = _nonembedding_active(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.step == StepKind.TRAIN:
        tokens = B * S
        f = 6.0 * N * tokens + 3.0 * attention_flops(cfg, S, B)
        # unembed fwd+bwd
        f += 6.0 * cfg.d_model * cfg.vocab_size * tokens
    elif shape.step == StepKind.PREFILL:
        tokens = B * S
        f = 2.0 * N * tokens + attention_flops(cfg, S, B)
        f += 2.0 * cfg.d_model * cfg.vocab_size * B  # last-token logits only
    else:  # DECODE: one token per sequence, KV length = seq_len
        tokens = B
        f = 2.0 * N * tokens + attention_flops(cfg, 1, B, kv_len=S)
        f += 2.0 * cfg.d_model * cfg.vocab_size * B
    return {"model_flops": f, "tokens": float(tokens)}
