"""repro: production-grade JAX reproduction of "Junctiond: Extending FaaS
Runtimes with Kernel-Bypass" (CS.DC 2024) — a kernel-bypass FaaS serving
runtime adapted to TPU model serving, with 10 assigned architectures,
multi-pod GSPMD distribution, and Pallas TPU kernels."""
__version__ = "1.0.0"
