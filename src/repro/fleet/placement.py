"""Pluggable gateway placement policies.

A :class:`PlacementPolicy` picks which worker serves an invocation among
the workers that are *ready* for the function (image present + function
deployed).  Policies are registered by ``kind`` in a small registry
mirroring the execution-backend registry (``@register_placement`` /
``resolve_placement``), so scenarios and the CLI can name them by
string and new policies plug in without touching the gateway.

All policies are deterministic: ties break on worker id and the only
hashing used (locality) is ``zlib.crc32``, which is stable across
processes and immune to ``PYTHONHASHSEED``.
"""

from __future__ import annotations

import abc
import zlib
from typing import TYPE_CHECKING, Dict, List, Sequence, Type

if TYPE_CHECKING:  # pragma: no cover
    from repro.fleet.cluster import Worker

_PLACEMENTS: Dict[str, Type["PlacementPolicy"]] = {}


def register_placement(cls: Type["PlacementPolicy"]) -> Type["PlacementPolicy"]:
    """Class decorator: register a placement policy under ``cls.kind``."""
    kind = getattr(cls, "kind", "")
    if not kind:
        raise ValueError(f"{cls.__name__} must define a non-empty 'kind'")
    if kind in _PLACEMENTS:
        raise ValueError(f"placement policy {kind!r} already registered")
    _PLACEMENTS[kind] = cls
    return cls


def available_placements() -> List[str]:
    return sorted(_PLACEMENTS)


def resolve_placement(policy) -> "PlacementPolicy":
    """Resolve a policy name (or pass through an instance) to a fresh
    policy object.  Policies hold per-cluster state (round-robin
    cursors), so names always resolve to a new instance."""
    if isinstance(policy, PlacementPolicy):
        return policy
    if policy in _PLACEMENTS:
        return _PLACEMENTS[policy]()
    raise ValueError(
        f"unknown placement policy {policy!r}; "
        f"available: {', '.join(available_placements())}"
    )


class PlacementPolicy(abc.ABC):
    """Picks a worker for one invocation among the ready set.

    ``ready`` is always non-empty and sorted by worker id; the gateway
    handles the no-ready-worker case (reject or expand) itself.
    """

    kind: str = ""

    @abc.abstractmethod
    def pick(self, fn: str, ready: Sequence["Worker"]) -> "Worker":
        ...


@register_placement
class RoundRobinPlacement(PlacementPolicy):
    """Cycle through the ready workers per function."""

    kind = "round-robin"

    def __init__(self) -> None:
        self._cursor: Dict[str, int] = {}

    def pick(self, fn: str, ready: Sequence["Worker"]) -> "Worker":
        i = self._cursor.get(fn, 0)
        self._cursor[fn] = i + 1
        return ready[i % len(ready)]


@register_placement
class LeastLoadedPlacement(PlacementPolicy):
    """Send each invocation to the ready worker with the lowest
    outstanding-per-core load.

    Ties break on a rotating cursor, not a fixed worker id: at low
    load most workers sit at load 0, and a static tie-break would herd
    every invocation onto worker 0 (real least-connection balancers
    rotate or sample among ties for the same reason).
    """

    kind = "least-loaded"

    def __init__(self) -> None:
        self._cursor = 0

    def pick(self, fn: str, ready: Sequence["Worker"]) -> "Worker":
        return self.pick_min(fn, ready)[0]

    def pick_min(self, fn: str, ready: Sequence["Worker"]):
        """``(pick(fn, ready), min load)`` in one pass over the ready
        set — the gateway reuses the scanned minimum for its spill
        check instead of re-walking the fleet.  ``ready`` arrives in
        wid order, so tracking the first minimum-load worker and the
        first at-or-after the cursor reproduces the tie-rotation of the
        two-pass form exactly."""
        c = self._cursor
        lo = float("inf")
        first = ge = None
        try:
            # inlined Worker.load: this scan runs once per routed
            # request and dominates the fleet driver's wall time
            for w in ready:
                l = w.outstanding / (w.runtime.cores.n_cores or 1)
                if l < lo:
                    lo = l
                    first = w
                    ge = w if w.wid >= c else None
                elif l == lo and ge is None and w.wid >= c:
                    ge = w
        except AttributeError:      # duck-typed stand-ins expose .load
            lo = float("inf")
            first = ge = None
            for w in ready:
                l = w.load
                if l < lo:
                    lo = l
                    first = w
                    ge = w if w.wid >= c else None
                elif l == lo and ge is None and w.wid >= c:
                    ge = w
        w = ge if ge is not None else first
        self._cursor = w.wid + 1
        return w, lo


@register_placement
class LocalityPlacement(PlacementPolicy):
    """Sticky function->worker affinity with load-bounded spill.

    Each (function, worker) pair gets a stable rendezvous score
    (crc32), giving every function its own preference order over the
    ready set.  Invocations go to the most-preferred worker whose load
    is below ``spill_load``; when all preferred workers are saturated
    the policy degrades to least-loaded.  Under a Zipf tenant mix this
    concentrates warm state (snapshot caches, provider caches) for the
    tail functions on a few "home" workers instead of smearing it
    fleet-wide.
    """

    kind = "locality"

    def __init__(self, spill_load: float = 6.0) -> None:
        self.spill_load = spill_load

    def pick(self, fn: str, ready: Sequence["Worker"]) -> "Worker":
        order = sorted(
            ready,
            key=lambda w: zlib.crc32(f"{fn}|{w.wid}".encode()),
        )
        for w in order:
            if w.load < self.spill_load:
                return w
        return min(ready, key=lambda w: (w.load, w.wid))
