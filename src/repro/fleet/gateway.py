"""Gateway tier: admission + placement + pressure-driven expansion.

The gateway is the fleet's front door.  Every admitted invocation is
routed to one worker by the cluster's :class:`PlacementPolicy`; the
per-worker placement counts land in the artifact so placement skew is
observable.  When every ready worker for a function is saturated
(load >= ``spill_load``) and some worker lacks the function, the
gateway triggers an *expansion*: a one-replica provision onto the
least-loaded cold worker, paying the image-distribution cost mid-run.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Set

if TYPE_CHECKING:  # pragma: no cover
    from repro.fleet.cluster import Cluster, Worker
    from repro.fleet.placement import PlacementPolicy


class Gateway:

    __slots__ = ("cluster", "policy", "spill_load", "placements",
                 "expansions", "_expanding", "_ready_cache", "_pick_min")

    def __init__(self, cluster: "Cluster", policy: "PlacementPolicy",
                 spill_load: Optional[float] = 8.0):
        self.cluster = cluster
        self.policy = policy
        self.spill_load = spill_load
        self.placements = [0] * len(cluster.workers)
        self.expansions: List[Dict] = []
        self._expanding: Set[str] = set()
        # per-function ready Worker lists, invalidated by length when a
        # provision marks a new worker ready (workers are never removed)
        self._ready_cache: Dict[str, List["Worker"]] = {}
        self._pick_min = getattr(policy, "pick_min", None)

    def route(self, fn: str) -> Optional["Worker"]:
        """Pick the worker for one invocation of ``fn``; ``None`` means
        no worker is ready (the caller rejects)."""
        cl = self.cluster
        ids = cl.ready.get(fn)
        if not ids:
            return None
        ready = self._ready_cache.get(fn)
        if ready is None or len(ready) != len(ids):
            ready = [cl.workers[i] for i in ids]
            self._ready_cache[fn] = ready
        pick_min = self._pick_min
        if pick_min is not None:
            w, lo = pick_min(fn, ready)
        else:
            w = self.policy.pick(fn, ready)
            lo = None
        self.placements[w.wid] += 1
        if (self.spill_load is not None
                and len(ids) < len(cl.workers)
                and fn not in self._expanding):
            if lo is None:
                lo = min(x.load for x in ready)
            if lo >= self.spill_load:
                self._expand(fn, ids)
        return w

    def _expand(self, fn: str, ready_ids) -> None:
        """Provision one replica of ``fn`` onto the least-loaded worker
        that lacks it (image pull charged via the distribution model)."""
        cl = self.cluster
        ready = set(ready_ids)
        target = min((w for w in cl.workers if w.wid not in ready),
                     key=lambda w: (w.load, w.wid))
        spec = dataclasses.replace(cl.functions[fn], scale=1)
        self._expanding.add(fn)
        t_req = cl.sim.now

        def go():
            try:
                pulled = yield from cl.provision(spec, target.wid)
                self.expansions.append({
                    "fn": fn, "worker": target.wid, "pulled": pulled,
                    "t_request_s": round(t_req, 6),
                    "ready_ms": round((cl.sim.now - t_req) * 1e3, 3)})
            finally:
                self._expanding.discard(fn)

        cl.sim.process(go())
