"""Fleet driver: the event-heap open loop over a Cluster.

``drive(cluster, load)`` lands here (dispatched by
:func:`repro.core.workload.drive`).  One shared arrival stream is
sampled from the cluster simulator's rng, each arrival is routed to a
worker by the gateway at admit time, and the invocation then runs the
same hop-compressed station machine as the single-runtime event engine
— against the *routed worker's* core pool, records, and net stack — so
per-worker contention, thrash, and autoscaler signals stay faithful.

Cost-table pre-sampling is global: same-backend workers share identical
``InvocationPlan``\\ s, so the per-request hold/gap/off-path matrices are
drawn once per function (one vectorized batch) regardless of fleet
size.  Everything runs on the cluster's one clock and heap, so a
same-seed fleet run is byte-identical.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional

import numpy as np

from repro.core.faas import InvocationPlan, InvocationRecord
from repro.core.simulator import EventLoop
from repro.core.workload import (LatencySummary, LoadSpec, NullObserver,
                                 SimObserver, _completion_rps, percentile)
from repro.fleet.cluster import Cluster


def drive_cluster(cluster: Cluster, load: LoadSpec,
                  obs: SimObserver) -> Dict[str, object]:
    sim = cluster.sim
    fn_names = load.functions
    duration_s = load.duration_s
    warmup_s = load.effective_warmup_s
    drain_s = load.drain_s
    max_out = load.max_outstanding
    t0 = sim.now
    rel = load.arrivals.times(sim.rng, duration_s)
    n = len(rel)
    if len(fn_names) > 1:
        picks = sim.rng.choice(len(fn_names), size=n,
                               p=load.normalized_weights())
    else:
        picks = np.zeros(n, dtype=np.intp)

    H = np.empty((n, 3))            # station CPU holds
    G = np.empty((n, 2))            # inter-station latency gaps
    OFF = np.empty(n)               # merged off-path CPU job
    EX = np.empty(n)                # exec-span approximation for records
    stack_cpu = [0.0] * len(fn_names)
    for f, nm in enumerate(fn_names):
        mask = picks == f
        m = int(mask.sum())
        if m == 0:
            continue
        ref = cluster.reference_runtime(nm)
        plan = ref.invocation_plan(nm)
        h, g, off, ex, n_hic = plan.sample(sim.rng, m)
        H[mask] = h
        G[mask] = g
        OFF[mask] = off
        EX[mask] = ex
        stack_cpu[f] = plan.stack_cpu_s
        # hiccups are sampled per function batch, before routing is
        # known; book them on the reference worker's stack
        ref.stack.hiccups += n_hic

    HL = H.tolist()
    GL = G.tolist()
    OFFL = OFF.tolist()
    EXL = EX.tolist()
    ATL = (t0 + rel).tolist()
    picksL = picks.tolist()
    ex_start = [0.0] * n
    wid_of = [-1] * n               # routed worker per request

    workers = cluster.workers
    pools = [w.runtime.cores for w in workers]
    route = cluster.gateway.route
    heap = sim._heap
    push = heapq.heappush
    counter = sim._counter
    st_weight = InvocationPlan.STATION_BACKLOG_WEIGHT
    off_weight = InvocationPlan.OFFPATH_BACKLOG_WEIGHT
    observed = not isinstance(obs, NullObserver)
    autoscaled = any(w.autoscaler is not None for w in workers)
    t_warm = t0 + warmup_s
    outstanding = 0
    admitted = 0
    rejected0 = cluster.rejected
    done_recs: List[InvocationRecord] = []
    lat_by_worker: List[List[float]] = [[] for _ in workers]

    def _grant(start, i, k):
        pool = pools[wid_of[i]]
        eff = HL[i][k] * pool.thrash()
        push(heap, (start + eff, next(counter), _complete, (i, k, eff, start)))

    def _off_grant(start, wid, off):
        pool = pools[wid]
        eff = off * pool.thrash()
        push(heap, (start + eff, next(counter), _off_done, (wid, eff)))

    def _off_done(wid, eff):
        pools[wid].release_fast(eff)

    def _complete(i, k, eff, start):
        nonlocal outstanding
        wid = wid_of[i]
        pool = pools[wid]
        pool.release_fast(eff)
        now = start + eff
        if k == 2:
            outstanding -= 1
            w = workers[wid]
            w.outstanding -= 1
            rec = InvocationRecord(fn=fn_names[picksL[i]], t_arrival=ATL[i])
            rec.t_start_exec = ex_start[i]
            rec.t_end_exec = ex_start[i] + EXL[i]
            rec.t_done = now
            w.runtime.records.append(rec)
            done_recs.append(rec)
            if ATL[i] >= t_warm:
                lat_by_worker[wid].append((now - ATL[i]) * 1e3)
            if autoscaled and w.autoscaler is not None:
                w.autoscaler.on_done(rec.fn)
            if observed:
                obs.on_done(rec.fn)
            return
        if k == 0:
            off = OFFL[i]
            if off > 0.0:
                pool.acquire_fast(now, _off_grant, (wid, off),
                                  weight=off_weight)
        else:
            ex_start[i] = start
        pool.acquire_fast(now + GL[i][k], _grant, (i, k + 1),
                          weight=st_weight)

    def _admit(i, t):
        nonlocal outstanding, admitted
        f = picksL[i]
        if outstanding >= max_out:
            cluster.rejected += 1
            return
        w = route(fn_names[f])
        if w is None:
            cluster.rejected += 1
            return
        wid_of[i] = w.wid
        outstanding += 1
        w.outstanding += 1
        w.admitted += 1
        if t >= t_warm:
            admitted += 1
        rt = w.runtime
        rt.cache_hits += 1          # warm cached resolve per request
        rt.stack.messages += 4
        rt.stack.cpu_spent += stack_cpu[f]
        if autoscaled and w.autoscaler is not None:
            w.autoscaler.on_arrival(fn_names[f])
        if observed:
            obs.on_arrival(fn_names[f])
        pools[w.wid].acquire_fast(t, _grant, (i, 0), weight=st_weight)

    EventLoop(sim).run(t0 + duration_s + drain_s, ATL, _admit)

    # -- assembly (mirrors workload._assemble over the fleet) -----------
    recs = [r for r in done_recs if r.t_arrival >= t_warm]
    done = [r for r in recs if r.t_done <= t0 + duration_s + drain_s]
    lat = [r.e2e * 1e3 for r in recs]
    summary = LatencySummary.of(lat)
    per_fn: Dict[str, LatencySummary] = {}
    for name in fn_names:
        fn_lat = [r.e2e * 1e3 for r in recs if r.fn == name]
        if fn_lat:
            per_fn[name] = LatencySummary.of(fn_lat)
    gw = cluster.gateway
    worker_rows = []
    for w in workers:
        lats = lat_by_worker[w.wid]
        worker_rows.append({
            "worker": w.wid,
            "n": len(lats),
            "placements": gw.placements[w.wid],
            "median_ms": round(percentile(lats, 50), 4) if lats else None,
            "p99_ms": round(percentile(lats, 99), 4) if lats else None,
        })
    return {
        "offered_rps": n / max(duration_s, 1e-9),
        "achieved_rps": len(done) / max(1e-9, duration_s - warmup_s),
        "completion_rps": _completion_rps(done, t0 + warmup_s,
                                          t0 + duration_s),
        "completed_frac": len(done) / max(1, admitted),
        "median_ms": summary.median_ms,
        "p99_ms": summary.p99_ms,
        "mean_ms": summary.mean_ms,
        "p999_ms": summary.p999_ms,
        "n": summary.n,
        "rejected": cluster.rejected - rejected0,
        "per_fn": per_fn,
        "latencies_ms": lat,
        "fleet": {
            "n_workers": len(workers),
            "placement": gw.policy.kind,
            "distribution": cluster.distribution.kind,
            "workers": worker_rows,
            "expansions": list(gw.expansions),
        },
    }
