"""Fleet driver: the event-heap open loop over a Cluster.

``drive(cluster, load)`` lands here (dispatched by
:func:`repro.core.workload.drive`).  One shared arrival stream is
sampled from the cluster simulator's rng, each arrival is routed to a
worker by the gateway at admit time, and the invocation then runs the
same hop-compressed station machine as the single-runtime event engine
— against the *routed worker's* core pool, records, and net stack — so
per-worker contention, thrash, and autoscaler signals stay faithful.

Requests admitted into an uncontended worker pool take the fused fast
path (see ``repro.core.workload.FUSED_FAST_PATH``): one precomputed
completion event plus a lazy off-path core release, instead of the
~4-event station walk.  Contended admits fall back to the per-station
machine through ``CorePool.acquire_fast``.

Cost-table pre-sampling is global: same-backend workers share identical
``InvocationPlan``\\ s, so the per-request hold/gap/off-path matrices are
drawn once per function (one vectorized batch) regardless of fleet
size.  Everything runs on the cluster's one clock and heap, so a
same-seed fleet run is byte-identical.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional

import numpy as np

import repro.core.workload as _workload
from repro.core.faas import InvocationPlan, InvocationRecord
from repro.core.simulator import EventLoop
from repro.core.workload import (LatencySummary, LoadSpec, NullObserver,
                                 SimObserver, _chain_result, _expand_chains,
                                 _fused_arrays, _sample_chain_matrices)
from repro.fleet.cluster import Cluster


def _apportion(total: int, counts: List[int]) -> List[int]:
    """Largest-remainder apportionment of ``total`` integer units over
    buckets proportional to ``counts`` (ties broken by lower index, so
    the split is deterministic)."""
    weight = sum(counts)
    if weight <= 0 or total <= 0:
        return [0] * len(counts)
    quotas = [total * c / weight for c in counts]
    shares = [int(q) for q in quotas]
    left = total - sum(shares)
    if left > 0:
        order = sorted(range(len(counts)),
                       key=lambda j: (shares[j] - quotas[j], j))
        for j in order[:left]:
            shares[j] += 1
    return shares


def drive_cluster(cluster: Cluster, load: LoadSpec,
                  obs: SimObserver) -> Dict[str, object]:
    sim = cluster.sim
    fn_names = load.functions
    n_fn = len(fn_names)
    duration_s = load.duration_s
    warmup_s = load.effective_warmup_s
    drain_s = load.drain_s
    max_out = load.max_outstanding
    t0 = sim.now
    rel = load.arrivals.times(sim.rng, duration_s)
    n = len(rel)
    if n_fn > 1 or load.chains is not None:
        # chained runs always draw picks so the trigger-draw stream
        # that follows stays aligned with the single-runtime engines'
        picks = sim.rng.choice(n_fn, size=n, p=load.normalized_weights())
    else:
        picks = np.zeros(n, dtype=np.intp)
    table = _expand_chains(load, picks, sim.rng,
                           cluster.workers[0].runtime.backend_name)

    AT = t0 + rel
    SC = None
    if table is None:
        N = n
        H = np.empty((n, 3))        # station CPU holds
        G = np.empty((n, 2))        # inter-station latency gaps
        OFF = np.empty(n)           # merged off-path CPU job
        EX = np.empty(n)            # exec-span approximation for records
        stack_cpu = [0.0] * n_fn
        hic_of_fn = [0] * n_fn
        for f, nm in enumerate(fn_names):
            mask = picks == f
            m = int(mask.sum())
            if m == 0:
                continue
            plan = cluster.reference_runtime(nm).invocation_plan(nm)
            h, g, off, ex, n_hic = plan.sample(sim.rng, m)
            H[mask] = h
            G[mask] = g
            OFF[mask] = off
            EX[mask] = ex
            stack_cpu[f] = plan.stack_cpu_s
            # hiccups are sampled per function batch, before routing is
            # known; they are apportioned across the routed workers
            # after the run (see below)
            hic_of_fn[f] = n_hic
    else:
        fn_names = table.fn_names
        n_fn = len(fn_names)
        picks = np.asarray(table.fidx, dtype=np.intp)
        N = int(picks.size)
        H, G, OFF, EX, SC, hic_of_fn = _sample_chain_matrices(
            cluster.reference_runtime, table, sim.rng)

    # flat structure-of-arrays buffers (station holds indexed 3*i+k,
    # gaps 2*i+k) plus the precomputed fused timelines
    H3 = H.ravel().tolist()
    G2 = G.ravel().tolist()
    OFFL = OFF.tolist()
    picksL = picks.tolist()
    if table is None:
        ATL = AT.tolist()
        rootATL = ATL
        ENDL, OFFENDL, CPUL, EXSL, EXEL = _fused_arrays(AT, H, G, OFF, EX)
        ex_start = list(EXSL)       # station machine overwrites its rows
    else:
        # a hop's arrival time is only known when its parent completes:
        # keep the fused timeline relative; _enter stamps the absolutes
        rootATL = AT.tolist()
        ATL = [0.0] * N
        SPANL = (H.sum(axis=1) + G.sum(axis=1)).tolist()
        OFFRELL = (H[:, 0] + OFF).tolist()
        H0G0L = (H[:, 0] + G[:, 0]).tolist()
        ENDL = [0.0] * N
        OFFENDL = [0.0] * N
        ex_start = [0.0] * N
    done_t = [0.0] * N              # completion time; 0.0 = not completed
    wid_of = [-1] * N               # routed worker per request
    fused = bytearray(N)            # fused admits; accounted post-loop

    workers = cluster.workers
    n_workers = len(workers)
    pools = [w.runtime.cores for w in workers]
    route = cluster.gateway.route
    heap = sim._heap
    push = heapq.heappush
    hpush = heapq.heappush
    hpop = heapq.heappop
    counter = sim._counter
    st_weight = InvocationPlan.STATION_BACKLOG_WEIGHT
    off_weight = InvocationPlan.OFFPATH_BACKLOG_WEIGHT
    observed = not isinstance(obs, NullObserver)
    autoscaled = any(w.autoscaler is not None for w in workers)
    fuse = _workload.FUSED_FAST_PATH
    check = _workload.SIM_CHECK
    t_warm = t0 + warmup_s
    outstanding = 0
    admitted = 0
    hop_rejected = 0
    rejected0 = cluster.rejected
    CHILD = table.children if table is not None else None
    # admits per (function, worker): drives the deferred netstack
    # accounting and the hiccup apportionment
    fw_count = [0] * (n_fn * n_workers)

    def _grant(start, i, k):
        pool = pools[wid_of[i]]
        eff = H3[3 * i + k] * pool.thrash()
        push(heap, (start + eff, next(counter), _complete, (i, k, eff, start)))

    def _off_grant(start, wid, off):
        pool = pools[wid]
        eff = off * pool.thrash()
        push(heap, (start + eff, next(counter), _off_done, (wid, eff)))

    def _off_done(wid, eff):
        pools[wid].release_fast(eff)

    def _fused_done(i):
        # one event for the whole fused request: release the routed
        # worker's on-path core and finish (records, latency rows and
        # busy_time/served accounting are materialised after the loop)
        nonlocal outstanding
        wid = wid_of[i]
        pool = pools[wid]
        pool.busy -= 1
        if pool._waiters:
            pool._grant_next()
        outstanding -= 1
        w = workers[wid]
        w.outstanding -= 1
        end = ENDL[i]
        done_t[i] = end
        if autoscaled and w.autoscaler is not None:
            w.autoscaler.on_done(fn_names[picksL[i]])
        if observed:
            obs.on_done(fn_names[picksL[i]])
        if CHILD is not None:
            for c in CHILD[i]:
                _enter(c, end)

    def _complete(i, k, eff, start):
        nonlocal outstanding
        wid = wid_of[i]
        pool = pools[wid]
        pool.release_fast(eff)
        now = start + eff
        if k == 2:
            outstanding -= 1
            w = workers[wid]
            w.outstanding -= 1
            done_t[i] = now
            if autoscaled and w.autoscaler is not None:
                w.autoscaler.on_done(fn_names[picksL[i]])
            if observed:
                obs.on_done(fn_names[picksL[i]])
            if CHILD is not None:
                for c in CHILD[i]:
                    _enter(c, now)
            return
        if k == 0:
            off = OFFL[i]
            if off > 0.0:
                pool.acquire_fast(now, _off_grant, (wid, off),
                                  weight=off_weight)
        else:
            ex_start[i] = start
        pool.acquire_fast(now + G2[2 * i + k], _grant, (i, k + 1),
                          weight=st_weight)

    def _admit(i, t):
        nonlocal outstanding, admitted
        f = picksL[i]
        if outstanding >= max_out:
            cluster.rejected += 1
            return
        w = route(fn_names[f])
        if w is None:
            cluster.rejected += 1
            return
        wid = w.wid
        wid_of[i] = wid
        outstanding += 1
        w.outstanding += 1
        w.admitted += 1
        fw_count[f * n_workers + wid] += 1
        if t >= t_warm:
            admitted += 1
        if autoscaled and w.autoscaler is not None:
            w.autoscaler.on_arrival(fn_names[f])
        if observed:
            obs.on_arrival(fn_names[f])
        pool = pools[wid]
        off_pend = pool._off_pend
        while off_pend and off_pend[0] <= t:    # expired lazy releases
            hpop(off_pend)
            pool.busy -= 1
        if fuse and not pool._waiters:
            b = pool.busy
            off = OFFL[i]
            if off > 0.0:
                if b + 2 < pool.n_cores:
                    if check:
                        _workload._fused_admit_check(pool, t, ENDL[i],
                                                     OFFENDL[i])
                    pool.busy = b + 2
                    fused[i] = 1
                    push(heap, (ENDL[i], next(counter), _fused_done, (i,)))
                    hpush(off_pend, OFFENDL[i])
                    return
            elif b + 1 < pool.n_cores:
                if check:
                    _workload._fused_admit_check(pool, t, ENDL[i])
                pool.busy = b + 1
                fused[i] = 1
                push(heap, (ENDL[i], next(counter), _fused_done, (i,)))
                return
        pool.acquire_fast(t, _grant, (i, 0), weight=st_weight)

    if table is not None:
        DEPTHL = table.depth
        SPANL_ = SPANL
        OFFRELL_ = OFFRELL
        H0G0L_ = H0G0L

        def _enter(i, t):
            # a root arrival or a spawned chain hop: stamp its absolute
            # fused timeline, then route through the gateway as usual
            nonlocal hop_rejected
            ATL[i] = t
            ENDL[i] = t + SPANL_[i]
            OFFENDL[i] = t + OFFRELL_[i]
            ex_start[i] = t + H0G0L_[i]
            r0 = cluster.rejected
            _admit(i, t)
            if cluster.rejected > r0 and DEPTHL[i]:
                hop_rejected += 1

        EventLoop(sim).run(t0 + duration_s + drain_s, rootATL, _enter)
    else:
        _enter = None
        EventLoop(sim).run(t0 + duration_s + drain_s, ATL, _admit)

    # -- deferred per-request accounting --------------------------------
    dt = np.asarray(done_t)
    wids = np.asarray(wid_of)
    fmask = np.frombuffer(fused, dtype=np.uint8).astype(bool) & (dt > 0.0)
    CPU = H.sum(axis=1) + OFF
    exs = np.asarray(ex_start)
    ex_end = exs + EX
    if table is not None:
        AT = np.asarray(ATL)        # hops got their times at spawn
    comp = dt > 0.0
    warm = comp & (AT >= t_warm)
    lat_ms = (dt - AT) * 1e3
    for w in workers:
        wid = w.wid
        rt = w.runtime
        adm = sum(fw_count[f * n_workers + wid] for f in range(n_fn))
        rt.cache_hits += adm        # warm cached resolve per request
        rt.stack.messages += 4 * adm
        wmask = wids == wid
        if SC is None:
            rt.stack.cpu_spent += sum(
                stack_cpu[f] * fw_count[f * n_workers + wid]
                for f in range(n_fn))
        else:
            # chained runs: per-row netstack CPU (payload scales vary
            # within a function), booked on the routed worker
            rt.stack.cpu_spent += float(SC[wmask].sum())
        wf = fmask & wmask
        pool = pools[wid]
        pool.busy_time += float(CPU[wf].sum())
        pool.served += int(3 * wf.sum() + np.count_nonzero(wf & (OFF > 0.0)))
        # records in completion order, on the routed worker's runtime
        widx = np.flatnonzero(comp & wmask)
        widx = widx[np.argsort(dt[widx], kind="stable")]
        append = rt.records.append
        for i in widx.tolist():
            append(InvocationRecord(fn_names[picksL[i]], ATL[i],
                                    float(exs[i]), float(ex_end[i]),
                                    done_t[i]))

    # hiccups: apportion each function's sampled count across the
    # workers its requests were actually routed to (largest remainder);
    # a function whose batch never routed keeps the pre-PR behaviour of
    # booking on its reference worker
    for f, nm in enumerate(fn_names):
        n_hic = hic_of_fn[f]
        if n_hic <= 0:
            continue
        counts = fw_count[f * n_workers:(f + 1) * n_workers]
        if sum(counts) == 0:
            cluster.reference_runtime(nm).stack.hiccups += n_hic
            continue
        for wid, share in enumerate(_apportion(n_hic, counts)):
            if share:
                workers[wid].runtime.stack.hiccups += share

    # -- assembly (vectorized; same schema as workload._events_result) --
    lat = lat_ms[warm]
    dmask = warm & (dt <= t0 + duration_s + drain_s)
    n_done = int(np.count_nonzero(dmask))
    summary = LatencySummary.of(lat)
    per_fn: Dict[str, LatencySummary] = {}
    pw = picks[warm]
    for f, name in enumerate(fn_names):
        fn_lat = lat[pw == f]
        if fn_lat.size:
            per_fn[name] = LatencySummary.of(fn_lat)
    if n_done:
        span = max(1e-9, max(float(dt[dmask].max()), t0 + duration_s)
                   - (t0 + warmup_s))
        completion_rps = n_done / span
    else:
        completion_rps = 0.0
    gw = cluster.gateway
    worker_rows = []
    for w in workers:
        wlat = lat_ms[warm & (wids == w.wid)]
        ws: Optional[LatencySummary] = \
            LatencySummary.of(wlat) if wlat.size else None
        worker_rows.append({
            "worker": w.wid,
            "n": int(wlat.size),
            "placements": gw.placements[w.wid],
            "median_ms": round(ws.median_ms, 4) if ws else None,
            "p99_ms": round(ws.p99_ms, 4) if ws else None,
        })
    chain_block = (None if table is None else
                   _chain_result(table, AT, done_t, EX, t_warm,
                                 hop_rejected))
    res = {
        "offered_rps": n / max(duration_s, 1e-9),
        "achieved_rps": n_done / max(1e-9, duration_s - warmup_s),
        "completion_rps": completion_rps,
        "completed_frac": n_done / max(1, admitted),
        "median_ms": summary.median_ms,
        "p99_ms": summary.p99_ms,
        "mean_ms": summary.mean_ms,
        "p999_ms": summary.p999_ms,
        "n": summary.n,
        "rejected": cluster.rejected - rejected0,
        "per_fn": per_fn,
        "latencies_ms": lat.tolist(),
        "fleet": {
            "n_workers": n_workers,
            "placement": gw.policy.kind,
            "distribution": cluster.distribution.kind,
            "workers": worker_rows,
            "expansions": list(gw.expansions),
        },
    }
    if chain_block is not None:
        res["chain"] = chain_block
    return res
