"""Fleet-scale simulation: gateway + N-worker cluster + provisioning.

Promotes the single ``FaasdRuntime`` to a simulated fleet (see
ROADMAP "Fleet"): a :class:`Cluster` of N per-worker runtimes behind a
:class:`Gateway` with pluggable placement, plus FaaSNet-style
function-image distribution charging provisioning storms.  Drive it
like any runtime: ``drive(cluster, load)``.
"""

from repro.fleet.cluster import Cluster, Worker
from repro.fleet.gateway import Gateway
from repro.fleet.placement import (LeastLoadedPlacement, LocalityPlacement,
                                   PlacementPolicy, RoundRobinPlacement,
                                   available_placements, register_placement,
                                   resolve_placement)
from repro.fleet.provisioning import (FaasNetTree, ImageDistribution,
                                      NaiveRegistryPull, PullRecord,
                                      SharedLink, available_distributions,
                                      register_distribution,
                                      resolve_distribution)

__all__ = [
    "Cluster",
    "Worker",
    "Gateway",
    "PlacementPolicy",
    "RoundRobinPlacement",
    "LeastLoadedPlacement",
    "LocalityPlacement",
    "register_placement",
    "resolve_placement",
    "available_placements",
    "ImageDistribution",
    "NaiveRegistryPull",
    "FaasNetTree",
    "SharedLink",
    "PullRecord",
    "register_distribution",
    "resolve_distribution",
    "available_distributions",
]
