"""Fleet cluster: N per-worker runtimes behind one gateway.

A :class:`Cluster` owns N :class:`Worker`\\ s — each a full
``FaasdRuntime`` with a registry-resolved execution backend, its own
``CorePool``/net stacks, and (optionally) its own ``Autoscaler`` — plus
one :class:`~repro.fleet.provisioning.ImageDistribution` model charging
image-transfer time whenever provisioning lands on a worker that does
not hold the function image.  All workers share the cluster's one
``Simulator`` clock and event heap, so cross-worker event ordering is
deterministic and a same-seed fleet run is byte-identical.

A cluster is driven exactly like a single runtime:
``drive(cluster, load)`` dispatches to the fleet driver, which routes
each arrival through the cluster's :class:`~repro.fleet.gateway.Gateway`.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Dict, Generator, List, Optional, Sequence

from repro.core.autoscaler import Autoscaler
from repro.core.faas import FaasdRuntime, FunctionSpec
from repro.core.simulator import Simulator
from repro.fleet.gateway import Gateway
from repro.fleet.placement import resolve_placement
from repro.fleet.provisioning import resolve_distribution


class Worker:
    """One fleet worker: a backend runtime plus gateway-visible state."""

    __slots__ = ("sim", "wid", "runtime", "images", "outstanding",
                 "admitted", "autoscaler")

    def __init__(self, sim: Simulator, wid: int, backend, n_cores: int):
        self.sim = sim
        self.wid = wid
        self.runtime = FaasdRuntime(sim, backend=backend, n_cores=n_cores)
        self.images: set = set()         # function images held locally
        self.outstanding = 0             # in-flight invocations
        self.admitted = 0                # lifetime routed invocations
        self.autoscaler: Optional[Autoscaler] = None

    @property
    def load(self) -> float:
        """Outstanding invocations per core — the gateway's load signal."""
        return self.outstanding / max(1, self.runtime.cores.n_cores)


class Cluster:
    """N workers + gateway + image-distribution model on one clock."""

    is_cluster = True

    def __init__(self, sim: Simulator, n_workers: int, *,
                 backend="containerd", n_cores: int = 10,
                 placement="least-loaded", distribution="tree",
                 image_mb: float = 256.0, origin_gbps: float = 10.0,
                 peer_gbps: float = 10.0, fanout: int = 2, chunks: int = 16,
                 spill_load: Optional[float] = 8.0,
                 scale_policy: Optional[Callable] = None):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        self.sim = sim
        self.image_mb = image_mb
        self.workers = [Worker(sim, wid, backend, n_cores)
                        for wid in range(n_workers)]
        if scale_policy is not None:
            for w in self.workers:
                w.autoscaler = Autoscaler(sim, w.runtime,
                                          policy=scale_policy())
                w.autoscaler.run()
        self.distribution = resolve_distribution(
            distribution, sim, origin_gbps=origin_gbps,
            peer_gbps=peer_gbps, fanout=fanout, chunks=chunks)
        self.functions: Dict[str, FunctionSpec] = {}
        self.ready: Dict[str, List[int]] = {}   # fn -> sorted worker ids
        self.gateway = Gateway(self, resolve_placement(placement),
                               spill_load=spill_load)
        self.rejected = 0
        self.storms: List[Dict] = []

    # -- topology helpers ----------------------------------------------
    def ready_workers(self, fn: str) -> List[Worker]:
        return [self.workers[i] for i in self.ready.get(fn, ())]

    def holders(self, fn: str) -> int:
        """Workers currently holding the function image."""
        return sum(1 for w in self.workers if fn in w.images)

    def reference_runtime(self, fn: str) -> FaasdRuntime:
        """A deployed runtime for cost-table lookups (tables are
        identical across same-backend workers)."""
        ids = self.ready.get(fn)
        if not ids:
            raise KeyError(f"function {fn!r} is not ready on any worker")
        return self.workers[ids[0]].runtime

    def _mark_ready(self, fn: str, wid: int) -> None:
        ids = self.ready.setdefault(fn, [])
        if wid not in ids:
            bisect.insort(ids, wid)

    # -- provisioning ---------------------------------------------------
    def provision(self, spec: FunctionSpec, wid: int, *,
                  pull: bool = True) -> Generator:
        """Process: land ``spec`` on worker ``wid`` — image transfer
        first (charged via the distribution model) if the worker does
        not hold it, then the backend's own deploy path.  Returns
        whether an image pull was charged."""
        w = self.workers[wid]
        pulled = False
        if pull and spec.name not in w.images:
            yield from self.distribution.fetch(
                spec.name, self.image_mb, wid, self.holders(spec.name))
            pulled = True
        w.images.add(spec.name)
        yield from w.runtime.deploy(spec)
        self.functions[spec.name] = spec
        self._mark_ready(spec.name, wid)
        return pulled

    def deploy_blocking(self, spec: FunctionSpec,
                        workers: Optional[Sequence[int]] = None) -> None:
        """Initial (pre-run) deployment: the image is considered
        pre-pulled — no distribution charge — on ``workers`` (default:
        all).  Blocks the caller by running the sim until every
        per-worker deploy completes."""
        targets = (list(range(len(self.workers))) if workers is None
                   else sorted(set(workers)))
        if not targets:
            raise ValueError("deploy_blocking needs at least one worker")
        remaining = [len(targets)]

        def one(wid: int) -> Generator:
            yield from self.provision(spec, wid, pull=False)
            remaining[0] -= 1
            if remaining[0] == 0:
                self.sim.stop()

        for wid in targets:
            self.sim.process(one(wid))
        self.sim.run()
        assert remaining[0] == 0, "fleet deploy did not converge"

    def scale_out(self, spec: FunctionSpec, total_replicas: int,
                  workers: Optional[Sequence[int]] = None) -> Generator:
        """Process: a provisioning storm — spread ``total_replicas`` of
        ``spec`` across ``workers`` (default: all), balanced; each
        worker pays an image pull (via the distribution model) if it
        lacks the image, then its backend's deploy cost.  Returns the
        storm record (also appended to ``self.storms``)."""
        if total_replicas < 1:
            raise ValueError(
                f"total_replicas must be >= 1, got {total_replicas}")
        targets = (list(range(len(self.workers))) if workers is None
                   else sorted(set(workers)))
        targets = targets[:total_replicas]   # never a zero-replica worker
        base, extra = divmod(total_replicas, len(targets))
        t0 = self.sim.now
        storm: Dict = {"fn": spec.name, "t_start_s": round(t0, 6),
                       "total_replicas": total_replicas,
                       "n_workers": len(targets), "workers": []}
        done = self.sim.event()
        remaining = [len(targets)]

        def one(wid: int, k: int) -> Generator:
            pulled = yield from self.provision(
                dataclasses.replace(spec, scale=k), wid)
            storm["workers"].append({
                "worker": wid, "replicas": k, "pulled": pulled,
                "t_ready_s": round(self.sim.now - t0, 6)})
            remaining[0] -= 1
            if remaining[0] == 0:
                done.succeed()

        for j, wid in enumerate(targets):
            self.sim.process(one(wid, base + (1 if j < extra else 0)))
        yield done
        storm["time_to_full_s"] = round(self.sim.now - t0, 6)
        storm["workers"].sort(key=lambda d: d["worker"])
        storm["pulls"] = self.distribution.pulls_for(spec.name)
        self.storms.append(storm)
        return storm
