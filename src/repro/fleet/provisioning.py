"""Function-image distribution models for fleet provisioning.

At single-worker scale a cold start is dominated by the runtime's boot
path; at 1000-replica storm scale the binding constraint shifts to
*getting the function image onto N workers* (FaaSNet, arXiv:2105.11229).
This module charges that cost.  Two models, registered by ``kind``:

``naive``
    Every worker pulls the full image from one origin registry over a
    shared uplink.  The uplink is a processor-sharing fluid link, so N
    concurrent pulls each see ``1/N`` of the bandwidth and time-to-full
    capacity grows linearly in N.

``tree``
    FaaSNet-style peer-to-peer binary tree.  The first worker (root)
    pulls from the origin; every worker that finishes serves up to
    ``fanout`` children from its own uplink, and a child starts
    streaming chunks as soon as its parent holds them (pipelined, so a
    child finishes roughly one chunk after its parent rather than one
    full image later).  Time-to-full grows ~logarithmically in N.

Both models run on the shared simulator clock; ``fetch`` is a process
generator the cluster yields from, and every completed transfer is
recorded as a :class:`PullRecord` for per-worker artifact timelines.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Type

from repro.core.simulator import Event, Simulator

# Residual bytes below this are float round-off, not real payload.
_DONE_EPS_BYTES = 0.5


@dataclasses.dataclass(frozen=True)
class PullRecord:
    """One completed image transfer onto one worker."""

    fn: str
    worker: int
    source: str        # "origin" | "peer"
    t_start: float     # request time (s, sim clock)
    t_ready: float     # transfer-complete time (s, sim clock)

    def as_dict(self) -> Dict[str, object]:
        return {
            "fn": self.fn,
            "worker": self.worker,
            "source": self.source,
            "t_start_s": round(self.t_start, 6),
            "t_ready_s": round(self.t_ready, 6),
        }


class SharedLink:
    """Processor-sharing fluid link: N concurrent transfers each see
    ``capacity/N``.  Deterministic: flows live in an insertion-ordered
    dict and completions are re-derived (version-tokened) whenever the
    flow set changes."""

    __slots__ = ("sim", "rate_Bps", "_flows", "_last_t", "_ver", "_next")

    def __init__(self, sim: Simulator, gbps: float):
        if gbps <= 0:
            raise ValueError(f"link bandwidth must be positive, got {gbps}")
        self.sim = sim
        self.rate_Bps = gbps * 1e9 / 8.0
        self._flows: Dict[Event, float] = {}   # event -> remaining bytes
        self._last_t = sim.now
        self._ver = 0
        self._next = 0

    @property
    def n_flows(self) -> int:
        return len(self._flows)

    def transfer(self, nbytes: float) -> Event:
        """Start a transfer; the returned event fires at completion."""
        if nbytes <= 0:
            raise ValueError(f"transfer size must be positive, got {nbytes}")
        self._advance()
        ev = Event(self.sim)
        self._flows[ev] = float(nbytes)
        self._resched()
        return ev

    def _advance(self) -> None:
        """Drain bytes for the elapsed interval at the current share."""
        now = self.sim.now
        if self._flows and now > self._last_t:
            drained = (now - self._last_t) * self.rate_Bps / len(self._flows)
            for ev in self._flows:
                self._flows[ev] -= drained
        self._last_t = now

    def _resched(self) -> None:
        """Re-derive the next completion after a membership change."""
        self._ver += 1
        if not self._flows:
            return
        rem_min = min(self._flows.values())
        dt = max(0.0, rem_min * len(self._flows) / self.rate_Bps)
        self.sim._schedule(dt, self._fire, self._ver)

    def _fire(self, ver: int) -> None:
        if ver != self._ver:   # stale: the flow set changed since
            return
        self._advance()
        done = [ev for ev, rem in self._flows.items()
                if rem <= _DONE_EPS_BYTES]
        for ev in done:
            del self._flows[ev]
        for ev in done:
            ev.succeed(self.sim.now)
        self._resched()


_DISTRIBUTIONS: Dict[str, Type["ImageDistribution"]] = {}


def register_distribution(cls: Type["ImageDistribution"]) -> Type["ImageDistribution"]:
    kind = getattr(cls, "kind", "")
    if not kind:
        raise ValueError(f"{cls.__name__} must define a non-empty 'kind'")
    if kind in _DISTRIBUTIONS:
        raise ValueError(f"image distribution {kind!r} already registered")
    _DISTRIBUTIONS[kind] = cls
    return cls


def available_distributions() -> List[str]:
    return sorted(_DISTRIBUTIONS)


def resolve_distribution(dist, sim: Simulator, **params) -> "ImageDistribution":
    if isinstance(dist, ImageDistribution):
        return dist
    if dist in _DISTRIBUTIONS:
        return _DISTRIBUTIONS[dist](sim, **params)
    raise ValueError(
        f"unknown image distribution {dist!r}; "
        f"available: {', '.join(available_distributions())}"
    )


class ImageDistribution:
    """Base: charges the time to land a function image on a worker.

    ``fetch`` is a generator the caller yields from; it returns only
    when the image is fully present on the requesting worker.
    """

    kind: str = ""

    def __init__(self, sim: Simulator, *, origin_gbps: float = 10.0,
                 peer_gbps: float = 10.0, fanout: int = 2,
                 chunks: int = 16):
        if fanout < 1:
            raise ValueError(f"fanout must be >= 1, got {fanout}")
        if chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {chunks}")
        self.sim = sim
        self.origin = SharedLink(sim, origin_gbps)
        self.peer_Bps = peer_gbps * 1e9 / 8.0
        self.fanout = fanout
        self.chunks = chunks
        self.pulls: List[PullRecord] = []

    def fetch(self, fn: str, size_mb: float, worker: int, holders: int):
        """Process generator: transfer ``size_mb`` onto ``worker``.

        ``holders`` is the number of workers that already held the
        image when the fetch was requested (0 for a cold fleet).
        """
        raise NotImplementedError

    def pulls_for(self, fn: str) -> List[Dict[str, object]]:
        return [p.as_dict() for p in self.pulls if p.fn == fn]

    def _record(self, fn: str, worker: int, source: str,
                t_start: float, t_ready: float) -> None:
        self.pulls.append(PullRecord(fn, worker, source, t_start, t_ready))


@register_distribution
class NaiveRegistryPull(ImageDistribution):
    """Every worker pulls the full image from the one origin registry;
    concurrent pulls share the origin uplink fairly."""

    kind = "naive"

    def fetch(self, fn: str, size_mb: float, worker: int, holders: int):
        t0 = self.sim.now
        yield self.origin.transfer(size_mb * 1e6)
        self._record(fn, worker, "origin", t0, self.sim.now)


class _TreeState:
    """Per-function wave state for the FaaSNet tree."""

    __slots__ = ("root_claimed", "slots", "waiters")

    def __init__(self) -> None:
        self.root_claimed = False
        # Each slot is the serving parent's own completion time; a
        # child streaming from that parent cannot finish earlier than
        # parent_done + one chunk.
        self.slots: Deque[float] = deque()
        self.waiters: Deque[Event] = deque()


@register_distribution
class FaasNetTree(ImageDistribution):
    """FaaSNet-style tree provisioning: the root pulls from the origin,
    finished workers each serve ``fanout`` children over their peer
    uplink, and children stream pipelined chunk-by-chunk behind their
    parent."""

    kind = "tree"

    def __init__(self, sim: Simulator, **params):
        super().__init__(sim, **params)
        self._state: Dict[str, _TreeState] = {}

    def fetch(self, fn: str, size_mb: float, worker: int, holders: int):
        size = size_mb * 1e6
        st = self._state.setdefault(fn, _TreeState())
        if holders > 0 and not st.root_claimed:
            # Warm seeds: workers that already hold the image serve as
            # ready parents, no origin round-trip needed.
            st.root_claimed = True
            self._release(st, [self.sim.now] * (self.fanout * holders))
        if not st.root_claimed:
            st.root_claimed = True
            t0 = self.sim.now
            yield self.origin.transfer(size)
            t_done = self.sim.now
            self._record(fn, worker, "origin", t0, t_done)
            self._release(st, [t_done] * self.fanout)
            return
        # Peer path: claim a serving slot (or queue for one).
        t0 = self.sim.now
        if st.slots:
            parent_done = st.slots.popleft()
        else:
            ev = Event(self.sim)
            st.waiters.append(ev)
            parent_done = yield ev
        t_start = self.sim.now
        rate = self.peer_Bps / self.fanout
        chunk_s = (size / self.chunks) / rate
        t_done = max(t_start + size / rate, parent_done + chunk_s)
        # Pipelining: this worker's children may start streaming the
        # chunks it already holds *now* — they just cannot finish
        # before this worker does (plus one chunk), which the released
        # completion time encodes.  This is what makes the tree depth
        # cost one chunk per level instead of one full image.
        self._release(st, [t_done] * self.fanout)
        yield self.sim.timeout(t_done - t_start)
        self._record(fn, worker, "peer", t0, self.sim.now)
        self._release(st, [parent_done])   # hand the parent's slot back

    def _release(self, st: _TreeState, parent_done_times) -> None:
        for pd in parent_done_times:
            if st.waiters:
                st.waiters.popleft().succeed(pd)
            else:
                st.slots.append(pd)
