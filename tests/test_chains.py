"""Function chains + platform-side fusion: ChainEdge/FusionPlan spec
validation, chain expansion invariants (hypothesis-backed), events-vs-
process engine agreement on chained mixes, fused-vs-unfused exact
agreement when no edges fuse, the per-hop platform-tax ordering across
the backend matrix (junctiond lowest — the chain-tax claim), fleet chain
runs with gateway-routed cross-worker hops, and the schema-v6 chain
artifact contract."""
import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (ChainEdge, FaasdRuntime, FunctionSpec, FusionPlan,
                        LoadSpec, PoissonArrivals, Simulator, drive)
from repro.core.workload import _expand_chains
from repro.experiments import build_artifact, validate_artifact
from repro.fleet import Cluster, Gateway, resolve_placement

ALL_BACKENDS = ("containerd", "junctiond", "quark", "wasm",
                "firecracker", "gvisor")


def _runtime(backend, seed=0, n_cores=8):
    sim = Simulator(seed=seed)
    return FaasdRuntime(sim, backend=backend, n_cores=n_cores)


def _deploy_pipeline(rt):
    for name in ("ingest", "transform", "store"):
        rt.deploy_blocking(FunctionSpec(name=name, max_cores=8))


def _pipeline_load(rate=300.0, duration_s=0.6, fusion=None, p2=1.0,
                   scale2=1.0, **kw):
    chains = {"ingest": (ChainEdge("transform"),),
              "transform": (ChainEdge("store", prob=p2,
                                      payload_scale=scale2),)}
    return LoadSpec(arrivals=PoissonArrivals(rate), functions=("ingest",),
                    duration_s=duration_s, chains=chains, fusion=fusion,
                    **kw)


def _chain_run(backend, seed=0, engine="events", **load_kw):
    rt = _runtime(backend, seed=seed)
    _deploy_pipeline(rt)
    res = drive(rt, _pipeline_load(**load_kw), engine=engine)
    return rt, res


# ---------------------------------------------------------------------------
# Spec validation.


def test_chain_edge_validation():
    with pytest.raises(ValueError):
        ChainEdge("")
    with pytest.raises(ValueError):
        ChainEdge("f", prob=0.0)
    with pytest.raises(ValueError):
        ChainEdge("f", prob=1.5)
    with pytest.raises(ValueError):
        ChainEdge("f", payload_scale=0.0)
    e = ChainEdge("f", prob=0.5, payload_scale=2.0)
    assert (e.target, e.prob, e.payload_scale) == ("f", 0.5, 2.0)


def test_fusion_plan_normalizes_and_matches():
    plan = FusionPlan(edges=(("a", "b"), ("a", "b"), ("b", "c")))
    assert plan.fuses("a", "b") and plan.fuses("b", "c")
    assert not plan.fuses("b", "a")
    assert plan.applies_to("containerd")        # backends=None -> all
    only = FusionPlan(edges=(("a", "b"),), backends=("containerd",))
    assert only.applies_to("containerd") and not only.applies_to("junctiond")


def test_loadspec_rejects_chain_cycles_and_orphan_fusion():
    cyc = {"a": (ChainEdge("b"),), "b": (ChainEdge("a"),)}
    with pytest.raises(ValueError, match="chain cycle"):
        LoadSpec(arrivals=PoissonArrivals(10.0), functions=("a",),
                 chains=cyc)
    with pytest.raises(ValueError, match="chain cycle"):
        LoadSpec(arrivals=PoissonArrivals(10.0), functions=("a",),
                 chains={"a": (ChainEdge("a"),)})
    with pytest.raises(ValueError, match="fusion requires chains"):
        LoadSpec(arrivals=PoissonArrivals(10.0), functions=("a",),
                 fusion=FusionPlan(edges=(("a", "b"),)))


# ---------------------------------------------------------------------------
# Expansion invariants (engine-independent, driven on the table directly).


def _expand(seed, n_roots, p2=1.0, fusion=None):
    load = _pipeline_load(p2=p2, fusion=fusion)
    picks = np.zeros(n_roots, dtype=np.intp)
    rng = np.random.default_rng(seed)
    return _expand_chains(load, picks, rng, "containerd")


def test_expansion_deterministic_and_prefix_closed():
    a, b = _expand(7, 50, p2=0.6), _expand(7, 50, p2=0.6)
    assert a.fidx == b.fidx and a.depth == b.depth and a.root == b.root
    # prob-1.0 edges fire always, sub-unit ones only below their parent:
    # hop counts are prefix-closed along the chain
    n_by_depth = [a.depth.count(d) for d in (0, 1, 2)]
    assert n_by_depth[0] == 50 == a.n_roots
    assert n_by_depth[1] == 50                  # prob 1.0
    assert 0 < n_by_depth[2] < 50               # prob 0.6, seed-dependent
    assert sum(n_by_depth) == len(a.fidx)


def test_expansion_is_independent_of_fusion_plan():
    """Trigger draws must not depend on which edges fuse: same seed ->
    the identical hop tree, fused hops just live in ``members``."""
    plan = FusionPlan(edges=(("ingest", "transform"),))
    a = _expand(3, 40, p2=0.5)
    f = _expand(3, 40, p2=0.5, fusion=plan)
    # unfused rows: roots + every triggered non-fused hop
    assert f.n_roots == a.n_roots == 40
    n_store_a = a.depth.count(2)
    n_store_f = f.depth.count(2)
    assert n_store_a == n_store_f               # identical trigger draws
    assert sum(len(m) for m in f.members) == 40  # one fused hop per root


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       p2=st.floats(min_value=0.05, max_value=1.0))
def test_expansion_invariants_hold_for_any_seed_and_prob(seed, p2):
    t = _expand(seed, 30, p2=p2)
    n = len(t.fidx)
    assert t.n_roots == 30
    assert len(t.depth) == len(t.root) == len(t.scale) == n
    # every row's root is a real root row; depths start at 0 there
    for i in range(n):
        r = t.root[i]
        assert 0 <= r < 30 and t.depth[r] == 0
    # children link downward only and cover every non-root row once
    seen = sorted(c for kids in t.children for c in kids)
    assert seen == list(range(30, n))
    for host, kids in enumerate(t.children):
        for c in kids:
            assert t.depth[c] == t.depth[host] + 1


# ---------------------------------------------------------------------------
# Engine agreement + determinism.


def test_events_and_process_engines_agree_on_chains():
    # pinned at a stable operating point: the engines draw different
    # randomness realizations from the same seed, so near the pool's
    # critical load one can tip into thrash collapse while the other
    # does not (metastability, not a booking bug — busy_time agrees
    # within ~2% at every uncontended rate)
    for seed in (0, 3):
        _, ev = _chain_run("containerd", seed=seed, engine="events",
                           rate=200.0)
        _, pr = _chain_run("containerd", seed=seed, engine="process",
                           rate=200.0)
        # same seed -> the identical expanded hop tree in both engines
        assert ev["n"] == pr["n"] > 100
        assert ev["chain"]["n_roots"] == pr["chain"]["n_roots"]
        assert [h["n"] for h in ev["chain"]["hops"]] == \
            [h["n"] for h in pr["chain"]["hops"]]
        assert ev["median_ms"] == pytest.approx(pr["median_ms"], rel=0.10)
        assert ev["chain"]["root_median_ms"] == \
            pytest.approx(pr["chain"]["root_median_ms"], rel=0.10)


def test_chain_run_same_seed_byte_identical():
    _, a = _chain_run("containerd", seed=9, p2=0.7)
    _, b = _chain_run("containerd", seed=9, p2=0.7)
    assert a["latencies_ms"] == b["latencies_ms"]
    assert json.dumps(a["chain"], sort_keys=True) == \
        json.dumps(b["chain"], sort_keys=True)


def test_empty_fusion_plan_matches_no_fusion_exactly():
    """A FusionPlan that fuses nothing must not perturb the run at all —
    the rng streams, hop trees and timings stay byte-identical."""
    _, plain = _chain_run("containerd", seed=4, p2=0.8, fusion=None)
    _, empty = _chain_run("containerd", seed=4, p2=0.8,
                          fusion=FusionPlan(edges=()))
    assert plain["latencies_ms"] == empty["latencies_ms"]
    assert json.dumps(plain["chain"], sort_keys=True) == \
        json.dumps(empty["chain"], sort_keys=True)


def test_fusion_improves_latency_and_pool_cost_on_containerd():
    plan = FusionPlan(edges=(("ingest", "transform"),
                             ("transform", "store")))
    rt_u, unfused = _chain_run("containerd", seed=2)
    rt_f, fused = _chain_run("containerd", seed=2, fusion=plan)
    assert fused["chain"]["fused_members"] > 0
    assert fused["chain"]["hops"] == fused["chain"]["hops"][:1]  # roots only
    assert fused["chain"]["root_p99_ms"] < unfused["chain"]["root_p99_ms"]
    assert fused["chain"]["root_median_ms"] < \
        unfused["chain"]["root_median_ms"]
    # fused hops skip the gateway + netstack stations entirely
    assert rt_f.cores.busy_time < 0.7 * rt_u.cores.busy_time


def test_linear_chain_latency_is_additive():
    """In a prob-1.0 linear chain each hop starts when its parent ends,
    so with no warmup filtering the root's e2e mean is exactly the sum
    of the per-hop latency means."""
    _, res = _chain_run("junctiond", seed=1, rate=150.0, warmup_frac=0.0)
    ch = res["chain"]
    assert ch["roots_completed"] == ch["n_roots"] > 50
    hop_ns = [h["n"] for h in ch["hops"]]
    assert hop_ns == [ch["n_roots"]] * 3
    assert ch["root_mean_ms"] == pytest.approx(
        sum(h["mean_ms"] for h in ch["hops"]), rel=1e-6)


def test_payload_scale_raises_downstream_hop_latency():
    _, small = _chain_run("containerd", seed=6, rate=150.0, scale2=1.0)
    _, big = _chain_run("containerd", seed=6, rate=150.0, scale2=16.0)
    h2_small = small["chain"]["hops"][2]["mean_ms"]
    h2_big = big["chain"]["hops"][2]["mean_ms"]
    assert h2_big > h2_small


# ---------------------------------------------------------------------------
# The chain-tax claim: per-hop platform overhead across the matrix.


def test_per_hop_tax_ordering_junctiond_lowest():
    """The acceptance pin: junctiond's per-hop platform tax is the
    lowest of the whole backend matrix, and containerd pays well over
    it (measured ~1.7-1.9x; gated conservatively at 1.3x)."""
    tax = {}
    for backend in ALL_BACKENDS:
        _, res = _chain_run(backend, seed=0)
        assert res["chain"]["rejected_hops"] == 0
        tax[backend] = res["chain"]["hop_tax_mean_ms"]
    others = {b: t for b, t in tax.items() if b != "junctiond"}
    assert tax["junctiond"] < min(others.values()), tax
    assert tax["containerd"] >= 1.3 * tax["junctiond"], tax


# ---------------------------------------------------------------------------
# Fleet: gateway-routed hops across workers.


class _SpyGateway(Gateway):
    """Records every routing decision: (fn, worker id)."""

    __slots__ = ("routed",)

    def __init__(self, cluster, policy, spill_load=None):
        super().__init__(cluster, policy, spill_load)
        self.routed = []

    def route(self, fn):
        w = super().route(fn)
        self.routed.append((fn, None if w is None else w.wid))
        return w


def _fleet_chain_run(seed=0, spy=False):
    sim = Simulator(seed=seed)
    cl = Cluster(sim, 4, backend="containerd", n_cores=8,
                 placement="round-robin")
    if spy:
        cl.gateway = _SpyGateway(cl, resolve_placement("round-robin"))
    for name in ("ingest", "transform", "store"):
        cl.deploy_blocking(FunctionSpec(name=name, max_cores=8))
    return cl, drive(cl, _pipeline_load(rate=400.0))


def test_fleet_chain_same_seed_byte_identical():
    _, a = _fleet_chain_run(seed=3)
    _, b = _fleet_chain_run(seed=3)
    assert a["latencies_ms"] == b["latencies_ms"]
    assert json.dumps(a["chain"], sort_keys=True) == \
        json.dumps(b["chain"], sort_keys=True)
    assert json.dumps(a["fleet"], sort_keys=True) == \
        json.dumps(b["fleet"], sort_keys=True)


def test_fleet_chain_hops_route_cross_worker():
    cl, res = _fleet_chain_run(seed=0, spy=True)
    assert res["chain"]["n_roots"] > 50
    assert [h["hop"] for h in res["chain"]["hops"]] == [0, 1, 2]
    # every hop re-enters the gateway as a request of its own...
    routed_fns = {fn for fn, _ in cl.gateway.routed}
    assert routed_fns == {"ingest", "transform", "store"}
    # ...and round-robin spreads a root's hops across distinct workers
    wids = {wid for fn, wid in cl.gateway.routed if fn == "transform"}
    assert len(wids) > 1


# ---------------------------------------------------------------------------
# Schema v6.


def _chain_result_stub():
    hop = {"hop": 0, "n": 10, "median_ms": 1.0, "p99_ms": 2.0,
           "mean_ms": 1.1, "tax_mean_ms": 0.4}
    return {"mode": "chain", "n": 30, "median_ms": 1.0, "p99_ms": 2.0,
            "chain": {"n_roots": 10, "roots_completed": 10,
                      "root_median_ms": 3.0, "root_p99_ms": 5.0,
                      "root_mean_ms": 3.2, "hop_tax_mean_ms": 0.4,
                      "rejected_hops": 0, "fused_members": 0,
                      "hops": [hop]}}


def _doc_with(result):
    return build_artifact("unit", [{
        "name": "s", "mode": "chain", "description": "d",
        "backend_set": ["containerd"],
        "backends": {"containerd": result}}], [], [])


def test_schema_v6_validates_chain_blocks():
    validate_artifact(_doc_with(_chain_result_stub()))
    # dropping the chain block off a chain-mode result is a violation
    bad = _chain_result_stub()
    del bad["chain"]
    with pytest.raises(ValueError, match="chain"):
        validate_artifact(_doc_with(bad))
    # hop rows must keep the per-hop breakdown keys
    bad = _chain_result_stub()
    del bad["chain"]["hops"][0]["tax_mean_ms"]
    with pytest.raises(ValueError, match="hops"):
        validate_artifact(_doc_with(bad))
    # a fusion block, when present, needs its comparison ratios
    bad = _chain_result_stub()
    bad["fusion"] = {"chain": _chain_result_stub()["chain"]}
    with pytest.raises(ValueError, match="fusion"):
        validate_artifact(_doc_with(bad))
    good = _chain_result_stub()
    good["fusion"] = {"chain": _chain_result_stub()["chain"],
                      "p99_improvement": 1.5, "pool_efficiency": 2.0}
    validate_artifact(_doc_with(good))
