"""End-to-end behaviour tests for the paper's system: the junctiond FaaS
runtime vs the containerd baseline, the centralized scheduler's scaling
property, provider caching, and cold starts."""
import pytest

from repro.core import (FaasdRuntime, FunctionSpec, JunctionInstance,
                        LatencySummary, PollingModel, Simulator,
                        run_sequential)
from repro.core.latency import (CONTAINERD_COLDSTART_MS,
                                JUNCTION_INSTANCE_INIT_MS, JUNCTION_RUNTIME)
from repro.core.resources import CorePool
from repro.core.scheduler import JunctionScheduler


def _runtime(backend, seed=0, **kw):
    sim = Simulator(seed=seed)
    rt = FaasdRuntime(sim, backend=backend, **kw)
    rt.deploy_blocking(FunctionSpec(name="aes"))
    return rt


# ---------------------------------------------------------------------------
# Paper-claim validation (Fig 5): the central reproduction gates.


def _fig5(backend, seeds=range(5)):
    e2e, ex = [], []
    for s in seeds:
        rt = _runtime(backend, seed=s)
        summ = run_sequential(rt, "aes", n=100)
        e2e.append(summ)
        ex.append(LatencySummary.of(rt.exec_latencies_ms()))
    import numpy as np
    med = float(np.mean([s.median_ms for s in e2e]))
    p99 = float(np.mean([s.p99_ms for s in e2e]))
    exm = float(np.mean([s.median_ms for s in ex]))
    exp = float(np.mean([s.p99_ms for s in ex]))
    return med, p99, exm, exp


def test_fig5_median_reduction_matches_paper():
    """Paper: junctiond reduces median e2e latency by 37.33%."""
    cm, _, _, _ = _fig5("containerd")
    jm, _, _, _ = _fig5("junctiond")
    reduction = 100 * (1 - jm / cm)
    assert 30.0 <= reduction <= 46.0, f"median reduction {reduction:.1f}% (paper: 37.33%)"


def test_fig5_p99_reduction_matches_paper():
    """Paper: junctiond reduces P99 e2e latency by 63.42%."""
    _, cp, _, _ = _fig5("containerd")
    _, jp, _, _ = _fig5("junctiond")
    reduction = 100 * (1 - jp / cp)
    assert 50.0 <= reduction <= 78.0, f"p99 reduction {reduction:.1f}% (paper: 63.42%)"


def test_fig5_exec_latency_reduction_matches_paper():
    """Paper: function execution median -35.3%, P99 -81%."""
    _, _, cem, cep = _fig5("containerd")
    _, _, jem, jep = _fig5("junctiond")
    med_red = 100 * (1 - jem / cem)
    p99_red = 100 * (1 - jep / cep)
    assert 28.0 <= med_red <= 43.0, f"exec median reduction {med_red:.1f}% (paper 35.3%)"
    assert 60.0 <= p99_red <= 95.0, f"exec p99 reduction {p99_red:.1f}% (paper 81%)"


# ---------------------------------------------------------------------------
# Cold start (paper §5: Junction instance init = 3.4 ms).


def test_cold_start_junction_vs_containerd():
    sim = Simulator()
    rt = FaasdRuntime(sim, backend="junctiond")
    t0 = sim.now
    rt.deploy_blocking(FunctionSpec(name="f1"))
    junction_cold = sim.now - t0
    assert junction_cold == pytest.approx(JUNCTION_INSTANCE_INIT_MS * 1e-3, rel=0.01)

    sim2 = Simulator()
    rt2 = FaasdRuntime(sim2, backend="containerd")
    t0 = sim2.now
    rt2.deploy_blocking(FunctionSpec(name="f1"))
    containerd_cold = sim2.now - t0
    assert containerd_cold == pytest.approx(CONTAINERD_COLDSTART_MS * 1e-3, rel=0.01)
    assert containerd_cold / junction_cold > 50   # orders of magnitude


# ---------------------------------------------------------------------------
# Scheduler scalability (paper §3: polling cost ∝ cores, not instances).


def test_centralized_polling_cost_independent_of_instances():
    def polling_cost(n_instances):
        sim = Simulator()
        pool = CorePool(sim, 10, JUNCTION_RUNTIME)
        sched = JunctionScheduler(sim, pool)
        for i in range(n_instances):
            inst = JunctionInstance(sim, f"f{i}")
            inst.ready = True
            sched.register(inst)
        sched.run()
        sim.run(until=0.05)
        return sched.polling_cost_per_iteration()

    c10, c1000 = polling_cost(10), polling_cost(1000)
    # idle instances must not add polling work: cost stays ~constant
    assert c1000 <= c10 * 2.0, (c10, c1000)


def test_per_instance_polling_consumes_cores():
    """Naive DPDK-style: every isolated instance burns one polling core."""
    sim = Simulator()
    pool = CorePool(sim, 10, JUNCTION_RUNTIME)
    sched = JunctionScheduler(sim, pool, PollingModel.PER_INSTANCE)
    for i in range(8):
        inst = JunctionInstance(sim, f"f{i}")
        sched.register(inst)
    assert pool.n_cores == 2              # 8 of 10 cores lost to polling
    assert sched.polling_cores_reserved == 8
    # centralized scheduler reserves exactly ONE core regardless
    sim2 = Simulator()
    pool2 = CorePool(sim2, 10, JUNCTION_RUNTIME)
    sched2 = JunctionScheduler(sim2, pool2)
    for i in range(8):
        inst = JunctionInstance(sim2, f"f{i}")
        sched2.register(inst)
    assert pool2.n_cores == 9
    assert sched2.polling_cores_reserved == 1


# ---------------------------------------------------------------------------
# Provider metadata cache (paper §4).


def test_provider_cache_removes_backend_query():
    rt = _runtime("containerd")
    run_sequential(rt, "aes", n=20)
    assert rt.cache_hits == 20
    assert rt.cache_misses == 0

    sim = Simulator()
    rt2 = FaasdRuntime(sim, backend="containerd", provider_cache=False)
    rt2.deploy_blocking(FunctionSpec(name="aes"))
    s_nocache = run_sequential(rt2, "aes", n=20)
    assert rt2.cache_misses == 20

    s_cache = run_sequential(_runtime("containerd"), "aes", n=20)
    # the containerd query (1.8ms) lands on the critical path without cache
    assert s_nocache.median_ms > s_cache.median_ms + 1.0


def test_invocation_records_are_complete():
    rt = _runtime("junctiond")
    run_sequential(rt, "aes", n=10)
    assert len(rt.records) == 10
    for r in rt.records:
        assert r.t_done > r.t_end_exec > r.t_start_exec > r.t_arrival
