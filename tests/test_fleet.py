"""Fleet tier: cluster + gateway + placement + image distribution.

Covers the pluggable registries (placement policies, distribution
models), the SharedLink fluid model, FaaSNet tree vs naive registry
provisioning (the >=3x storm claim CI gates on), gateway routing and
pressure-driven expansion, the ``drive(cluster, load)`` path with its
per-worker telemetry, same-seed byte-identical determinism — including
a recorded trace split across N workers with no duplicated or dropped
arrivals — and the schema-v5 fleet artifact contract.
"""
import json

import pytest

from repro.core import FaasdRuntime, FunctionSpec, LoadSpec, Simulator, drive
from repro.core.workload import TraceReplay
from repro.experiments import (FleetSpec, Scenario, build_artifact,
                               validate_artifact)
from repro.experiments.runner import _exec_fleet
from repro.experiments.scenario import ArrivalSpec, FunctionProfile
from repro.fleet import (Cluster, FaasNetTree, Gateway,
                         LeastLoadedPlacement, LocalityPlacement,
                         NaiveRegistryPull, RoundRobinPlacement, SharedLink,
                         available_distributions, available_placements,
                         resolve_distribution, resolve_placement)


# ---------------------------------------------------------------------------
# registries


def test_placement_registry():
    assert available_placements() == ["least-loaded", "locality",
                                      "round-robin"]
    pol = resolve_placement("round-robin")
    assert isinstance(pol, RoundRobinPlacement)
    # instances pass through; names mint fresh (stateful) instances
    assert resolve_placement(pol) is pol
    assert resolve_placement("round-robin") is not pol
    with pytest.raises(ValueError, match="unknown placement"):
        resolve_placement("bogus")


def test_distribution_registry():
    assert available_distributions() == ["naive", "tree"]
    sim = Simulator()
    assert isinstance(resolve_distribution("naive", sim), NaiveRegistryPull)
    assert isinstance(resolve_distribution("tree", sim), FaasNetTree)
    with pytest.raises(ValueError, match="unknown image distribution"):
        resolve_distribution("bogus", sim)


def test_distribution_parameter_validation():
    sim = Simulator()
    with pytest.raises(ValueError, match="bandwidth"):
        SharedLink(sim, 0.0)
    with pytest.raises(ValueError, match="fanout"):
        FaasNetTree(sim, fanout=0)
    with pytest.raises(ValueError, match="chunks"):
        FaasNetTree(sim, chunks=0)


# ---------------------------------------------------------------------------
# SharedLink fluid model


def test_shared_link_alone_runs_at_line_rate():
    sim = Simulator()
    link = SharedLink(sim, 8.0)            # 8 Gbps = 1e9 B/s
    done = []
    link.transfer(1e9).callbacks.append(lambda t: done.append(t))
    sim.run()
    assert done == [pytest.approx(1.0)]


def test_shared_link_processor_sharing():
    sim = Simulator()
    link = SharedLink(sim, 8.0)
    done = {}

    def start(name, delay, nbytes):
        def p():
            if delay:
                yield sim.timeout(delay)
            yield link.transfer(nbytes)
            done[name] = sim.now
        sim.process(p())

    # a runs alone for 0.5s (0.5e9 done), then shares with b: each
    # drains at 0.5e9 B/s, so a lands at 1.5 and b runs the last
    # 0.5e9 alone, landing at 2.0
    start("a", 0.0, 1e9)
    start("b", 0.5, 1e9)
    sim.run()
    assert done["a"] == pytest.approx(1.5)
    assert done["b"] == pytest.approx(2.0)


def test_shared_link_rejects_empty_transfer():
    with pytest.raises(ValueError, match="transfer size"):
        SharedLink(Simulator(), 8.0).transfer(0.0)


# ---------------------------------------------------------------------------
# placement policies (unit: fake workers)


class _W:
    def __init__(self, wid, load=0.0):
        self.wid = wid
        self.load = load


def test_round_robin_cycles_per_function():
    pol = RoundRobinPlacement()
    ready = [_W(0), _W(1), _W(2)]
    assert [pol.pick("f", ready).wid for _ in range(5)] == [0, 1, 2, 0, 1]
    # an independent cursor per function
    assert pol.pick("g", ready).wid == 0


def test_least_loaded_prefers_min_load_and_rotates_ties():
    pol = LeastLoadedPlacement()
    ready = [_W(0, 1.0), _W(1, 0.0), _W(2, 0.0)]
    picks = [pol.pick("f", ready).wid for _ in range(4)]
    # never the loaded worker; the tie-break cursor rotates instead of
    # herding onto the lowest id
    assert 0 not in picks
    assert set(picks) == {1, 2}


def test_locality_is_sticky_until_spill():
    pol = LocalityPlacement(spill_load=2.0)
    ready = [_W(0), _W(1), _W(2), _W(3)]
    home = pol.pick("fn-a", ready).wid
    assert all(pol.pick("fn-a", ready).wid == home for _ in range(8))
    # a different function may hash to a different home
    ready[home].load = 5.0                 # saturate the home worker
    spilled = pol.pick("fn-a", ready).wid
    assert spilled != home


# ---------------------------------------------------------------------------
# provisioning storms: tree vs naive


def _storm(distribution, n_workers=32, replicas=1000, seed=0,
           backend="containerd"):
    sim = Simulator(seed=seed)
    cl = Cluster(sim, n_workers, backend=backend, distribution=distribution)
    out = {}

    def go():
        rec = yield from cl.scale_out(FunctionSpec(name="storm-fn"),
                                      replicas)
        out.update(rec)
        sim.stop()

    sim.process(go())
    sim.run()
    assert out, "storm did not complete"
    return out


def test_tree_beats_naive_by_3x_at_storm_scale():
    tree = _storm("tree")
    naive = _storm("naive")
    assert tree["time_to_full_s"] > 0
    assert naive["time_to_full_s"] >= 3.0 * tree["time_to_full_s"], (
        tree["time_to_full_s"], naive["time_to_full_s"])


def test_storm_record_shape_and_pull_sources():
    rec = _storm("tree", n_workers=8, replicas=64)
    assert rec["n_workers"] == 8
    assert sum(w["replicas"] for w in rec["workers"]) == 64
    assert [w["worker"] for w in rec["workers"]] == list(range(8))
    assert all(w["pulled"] for w in rec["workers"])
    srcs = [p["source"] for p in rec["pulls"]]
    assert srcs.count("origin") == 1       # only the root hits the registry
    assert srcs.count("peer") == 7
    naive = _storm("naive", n_workers=8, replicas=64)
    assert all(p["source"] == "origin" for p in naive["pulls"])


def test_storm_is_deterministic():
    a = _storm("tree", n_workers=16, replicas=200, seed=3)
    b = _storm("tree", n_workers=16, replicas=200, seed=3)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_scale_out_validates_replicas():
    sim = Simulator()
    cl = Cluster(sim, 2)
    with pytest.raises(ValueError, match="total_replicas"):
        list(cl.scale_out(FunctionSpec(name="f"), 0))


def test_warm_holders_seed_the_tree():
    """A fetch onto a fleet that already holds the image somewhere must
    stream from the warm peers, never the origin."""
    sim = Simulator()
    cl = Cluster(sim, 4, distribution="tree")
    cl.deploy_blocking(FunctionSpec(name="aes"), workers=[0, 1])

    def go():
        yield from cl.provision(FunctionSpec(name="aes"), 2)
        sim.stop()

    sim.process(go())
    sim.run()
    assert [p["source"] for p in cl.distribution.pulls_for("aes")] == ["peer"]


# ---------------------------------------------------------------------------
# cluster + gateway


def test_cluster_validates_size():
    with pytest.raises(ValueError, match="n_workers"):
        Cluster(Simulator(), 0)


def test_deploy_blocking_marks_ready_without_pull_charge():
    sim = Simulator()
    cl = Cluster(sim, 4)
    cl.deploy_blocking(FunctionSpec(name="aes"))
    assert cl.ready["aes"] == [0, 1, 2, 3]
    assert cl.holders("aes") == 4
    assert cl.distribution.pulls == []     # pre-pulled: no transfer cost
    assert isinstance(cl.reference_runtime("aes"), FaasdRuntime)


def test_route_needs_a_ready_worker():
    sim = Simulator()
    cl = Cluster(sim, 2)
    assert cl.gateway.route("nope") is None
    with pytest.raises(KeyError):
        cl.reference_runtime("nope")


def test_gateway_routes_only_to_ready_subset_and_counts_placements():
    sim = Simulator()
    cl = Cluster(sim, 4, placement="round-robin", spill_load=None)
    cl.deploy_blocking(FunctionSpec(name="aes"), workers=[1, 3])
    wids = [cl.gateway.route("aes").wid for _ in range(6)]
    assert set(wids) == {1, 3}
    assert cl.gateway.placements == [0, 3, 0, 3]


def test_gateway_expands_under_pressure():
    sim = Simulator()
    cl = Cluster(sim, 3, spill_load=1.0)
    cl.deploy_blocking(FunctionSpec(name="aes"), workers=[0])
    cl.workers[0].outstanding = 50         # saturate the only ready worker
    w = cl.gateway.route("aes")
    assert w.wid == 0                      # still served by the ready set
    sim.run()                              # let the expansion land
    assert len(cl.gateway.expansions) == 1
    exp = cl.gateway.expansions[0]
    assert exp["fn"] == "aes" and exp["worker"] in (1, 2)
    assert exp["pulled"] and exp["ready_ms"] > 0
    assert sorted(cl.ready["aes"]) == [0, exp["worker"]]


# ---------------------------------------------------------------------------
# drive(cluster, load)


def _drive_fleet(seed=0, n_workers=4, placement="least-loaded",
                 rate=400.0, duration_s=1.0):
    sim = Simulator(seed=seed)
    cl = Cluster(sim, n_workers, placement=placement)
    cl.deploy_blocking(FunctionSpec(name="aes"))
    res = drive(cl, LoadSpec.single("aes", rate, duration_s=duration_s))
    return cl, res


def test_drive_cluster_result_row_and_worker_telemetry():
    cl, res = _drive_fleet()
    fl = res["fleet"]
    assert fl["n_workers"] == 4
    assert fl["placement"] == "least-loaded"
    assert fl["distribution"] == "tree"
    assert len(fl["workers"]) == 4
    assert res["rejected"] == 0
    assert res["n"] > 0 and res["median_ms"] > 0
    placed = sum(w["placements"] for w in fl["workers"])
    assert placed == sum(w.admitted for w in cl.workers)
    # least-loaded keeps the fleet balanced: no worker starves
    assert all(w["n"] > 0 for w in fl["workers"])


def test_drive_cluster_same_seed_is_byte_identical():
    _, a = _drive_fleet(seed=7)
    _, b = _drive_fleet(seed=7)
    assert a["latencies_ms"] == b["latencies_ms"]
    assert json.dumps(a["fleet"], sort_keys=True) == \
        json.dumps(b["fleet"], sort_keys=True)


def test_drive_cluster_rejects_process_engine():
    sim = Simulator()
    cl = Cluster(sim, 2)
    cl.deploy_blocking(FunctionSpec(name="aes"))
    load = LoadSpec.single("aes", 100.0, duration_s=0.5)
    with pytest.raises(ValueError, match="event engine"):
        drive(cl, load, engine="process")


def test_drive_cluster_requires_deployed_functions():
    sim = Simulator()
    cl = Cluster(sim, 2)
    with pytest.raises(KeyError, match="not deployed"):
        drive(cl, LoadSpec.single("aes", 100.0, duration_s=0.5))


# ---------------------------------------------------------------------------
# trace replay split across N workers (gateway fan-out determinism)


_TRACE = [i * 0.004 + (0.0007 * (i % 5)) for i in range(240)]


class _SpyGateway(Gateway):
    """Records every routing decision: (fn, arrival time, worker id)."""

    __slots__ = ("routed",)

    def __init__(self, cluster, policy, spill_load=None):
        super().__init__(cluster, policy, spill_load)
        self.routed = []

    def route(self, fn):
        w = super().route(fn)
        self.routed.append((fn, round(self.cluster.sim.now, 9),
                            None if w is None else w.wid))
        return w


def _drive_trace(seed=0, n_workers=4):
    """Replay a fixed trace over a small fleet, spying on the gateway to
    record the exact per-worker arrival streams."""
    sim = Simulator(seed=seed)
    cl = Cluster(sim, n_workers, placement="round-robin")
    cl.gateway = _SpyGateway(cl, resolve_placement("round-robin"))
    for fn in ("t0", "t1"):
        cl.deploy_blocking(FunctionSpec(name=fn))
    load = LoadSpec(arrivals=TraceReplay(trace_s=tuple(_TRACE)),
                    functions=("t0", "t1"), duration_s=1.2)
    t0 = sim.now                  # deploys already advanced the clock
    res = drive(cl, load)
    routed = [(fn, round(t - t0, 9), wid)
              for fn, t, wid in cl.gateway.routed]
    streams = {w.wid: [(fn, t) for fn, t, wid in routed if wid == w.wid]
               for w in cl.workers}
    return res, routed, streams


def test_trace_split_same_seed_identical_per_worker_streams():
    _, routed_a, streams_a = _drive_trace(seed=5)
    _, routed_b, streams_b = _drive_trace(seed=5)
    assert routed_a == routed_b
    # byte-identical per-worker arrival streams, not just equal counts
    assert json.dumps(streams_a, sort_keys=True, default=list) == \
        json.dumps(streams_b, sort_keys=True, default=list)


def test_trace_split_no_duplicated_or_dropped_arrivals():
    res, routed, streams = _drive_trace()
    assert res["rejected"] == 0
    # every trace arrival admitted exactly once across the fleet
    assert len(routed) == len(_TRACE)
    assert sum(len(s) for s in streams.values()) == len(_TRACE)
    times = sorted(t for s in streams.values() for _, t in s)
    assert times == sorted(round(t, 9) for t in _TRACE)
    # the split is a partition: each worker's stream is time-ordered
    for s in streams.values():
        ts = [t for _, t in s]
        assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# runner integration + schema v5


def _fleet_scenario(**fleet_kw):
    spec = FleetSpec(n_workers=4, placement="least-loaded",
                     distribution="tree",
                     compare_distributions=("naive",),
                     storm_replicas=32, storm_t_frac=0.25, **fleet_kw)
    return Scenario(
        name="fleet-unit", description="unit fleet",
        mode="fleet", functions=(FunctionProfile("aes"),),
        arrival=ArrivalSpec("poisson"), fleet=spec,
        rates={"*": (200.0,)}, duration_s=1.0, warmup_frac=0.1,
        seeds=(0,), backends=("containerd",))


def test_exec_fleet_builds_variant_grid_with_speedup():
    res = _exec_fleet(_fleet_scenario(), "containerd",
                      duration_scale=1.0, smoke=True)
    fl = res["fleet"]
    assert fl["n_workers"] == 4
    assert [v["distribution"] for v in fl["variants"]] == ["tree", "naive"]
    assert fl["tree_provisioning_speedup"] >= 1.0
    for var in fl["variants"]:
        assert len(var["workers"]) == 4
        assert all("placements" in w and "n" in w for w in var["workers"])
        assert var["time_to_full_s"] > 0
        assert var["storm"]["pulls"], "storm pull timeline missing"
        # the storm's per-worker merge lands in the worker blocks
        assert all("storm_replicas" in w for w in var["workers"])
    assert res["mode"] == "fleet" and res["n"] > 0


def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="n_workers"):
        FleetSpec(n_workers=0)
    with pytest.raises(ValueError, match="spread"):
        FleetSpec(spread="uniform")
    with pytest.raises(ValueError, match="storm_t_frac"):
        FleetSpec(storm_t_frac=1.5)
    spec = FleetSpec(compare_placements=("round-robin",))
    assert spec.placements() == ("least-loaded", "round-robin")
    assert spec.distributions() == ("tree",)


def _doc_with_fleet(fleet):
    return build_artifact("unit", [{
        "name": "s", "mode": "fleet", "description": "d",
        "backend_set": ["containerd"],
        "backends": {"containerd": {"fleet": fleet}}}], [], [])


def test_schema_v5_validates_fleet_blocks():
    good = {"n_workers": 2, "placement": "least-loaded",
            "distribution": "tree",
            "variants": [{"placement": "least-loaded",
                          "distribution": "tree",
                          "workers": [{"worker": 0, "n": 1,
                                       "placements": 1}]}]}
    validate_artifact(_doc_with_fleet(good))

    with pytest.raises(ValueError, match=r"fleet missing 'variants'"):
        validate_artifact(_doc_with_fleet(
            {"n_workers": 2, "placement": "p", "distribution": "d"}))
    bad_variant = dict(good, variants=[{"placement": "p"}])
    with pytest.raises(ValueError, match=r"variants\[0\] missing"):
        validate_artifact(_doc_with_fleet(bad_variant))
    bad_worker = dict(good, variants=[{
        "placement": "p", "distribution": "d", "workers": [{"worker": 0}]}])
    with pytest.raises(ValueError, match=r"workers\[0\] must have"):
        validate_artifact(_doc_with_fleet(bad_worker))
    with pytest.raises(ValueError, match="fleet must be an object"):
        validate_artifact(_doc_with_fleet([1, 2]))
