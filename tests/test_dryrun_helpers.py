"""Units for the dry-run machinery that don't need 512 devices: input
specs, probe layer counts, serving variants, roofline extrapolation."""
import jax
import pytest

from repro.analysis.roofline import ProbePoint, build_roofline, extrapolate
from repro.config import get_arch, get_shape
from repro.configs import ASSIGNED

# importing dryrun after jax is initialised is safe (env var no-op)
from repro.launch.dryrun import (cache_template, input_specs,
                                 probe_layer_counts, serving_variant,
                                 with_layers)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k", "long_500k"])
def test_input_specs_cover_all_pairs(arch, shape_name):
    cfg0 = get_arch(arch)
    shape = get_shape(shape_name)
    cfg, note = serving_variant(cfg0, shape)
    specs = input_specs(cfg, shape)
    B = shape.global_batch
    if shape_name in ("decode_32k", "long_500k"):
        assert specs["tokens"].shape == (B, 1)
        # the decode cache: ONE token against seq_len of context
        tpl = cache_template(cfg, B, shape.seq_len)
        leaves = jax.tree_util.tree_leaves(tpl)
        assert leaves, arch
        # no allocation: everything is ShapeDtypeStruct
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    else:
        key = "embeds" if (cfg.frontend and not cfg.encdec) else "tokens"
        assert specs[key].shape[0] == B
        assert specs[key].shape[1] == shape.seq_len
    if cfg.encdec is not None and shape_name not in ("decode_32k", "long_500k"):
        assert specs["enc_embeds"].shape[1] == cfg.encdec.max_source_positions
    if shape.step.value == "train_step":
        assert specs["labels"].shape == (B, shape.seq_len)


def test_long_context_policy():
    """SSM/hybrid/SWA run long_500k natively; full-attention archs get the
    documented SWA serving variant."""
    long = get_shape("long_500k")
    for arch in ("rwkv6-1.6b", "jamba-v0.1-52b", "mixtral-8x7b",
                 "h2o-danube-3-4b"):
        _, note = serving_variant(get_arch(arch), long)
        assert note == "", arch
    for arch in ("qwen3-1.7b", "deepseek-67b", "phi4-mini-3.8b",
                 "pixtral-12b"):
        cfg, note = serving_variant(get_arch(arch), long)
        assert "swa-serving-variant" in note, arch
        assert cfg.sliding_window == 4096


def test_probe_layer_counts():
    assert probe_layer_counts(get_arch("qwen3-1.7b")) == (2, 4)
    assert probe_layer_counts(get_arch("jamba-v0.1-52b")) == (8, 16)


def test_with_layers_scales_encoder_too():
    cfg = with_layers(get_arch("seamless-m4t-large-v2"), 2)
    assert cfg.n_layers == 2 and cfg.encdec.encoder_layers == 2


def test_roofline_extrapolation_linear():
    pa = ProbePoint(layers=2, flops=10.0, bytes_accessed=100.0, coll_bytes=4.0)
    pb = ProbePoint(layers=4, flops=18.0, bytes_accessed=160.0, coll_bytes=6.0)
    tot = extrapolate(pa, pb, layers=10)
    assert tot["flops"] == pytest.approx(2 + 4 * 10)     # base 2 + 4/layer
    assert tot["bytes"] == pytest.approx(40 + 30 * 10)
    assert tot["coll"] == pytest.approx(2 + 1 * 10)
    roof = build_roofline("a", "s", "m", 256, tot, model_flops=1e12)
    assert roof.bottleneck in ("compute", "memory", "collective")
    assert roof.step_time_s == max(roof.compute_s, roof.memory_s,
                                   roof.collective_s)
