"""Model-layer unit + property tests: scan utilities vs sequential oracle,
MoE capacity vs dense oracle, SWA ring-buffer equivalence, RoPE/mask
invariants, analytic vs actual parameter counts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.config import get_arch, reduced
from repro.models import moe as moe_mod
from repro.models import transformer as T
from repro.models.attention import causal_mask
from repro.models.layers import apply_rope
from repro.models.scan_utils import linear_scan_emit, linear_scan_ref


# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(
    T_=st.sampled_from([8, 16, 32, 64, 128]),
    chunk=st.sampled_from([4, 8, 16, 32, 64]),
    seed=st.integers(0, 10_000),
)
def test_property_chunked_scan_matches_sequential(T_, chunk, seed):
    """PROPERTY: the chunked associative scan == the sequential recurrence
    for any chunking."""
    key = jax.random.PRNGKey(seed)
    ka, kb, kh = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(ka, (T_, 4, 3)))
    b = jax.random.normal(kb, (T_, 4, 3)) * 0.3
    h0 = jax.random.normal(kh, (4, 3))

    def make_ab(cin):
        return cin

    def emit(h_prev, h_post, cin):
        return h_post

    hs, h_last = linear_scan_emit((a, b), h0, make_ab, emit, chunk=chunk)
    hs_ref, h_ref = linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(hs, hs_ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(h_last, h_ref, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
def test_moe_capacity_matches_dense_with_ample_capacity():
    cfg = dataclasses.replace(reduced(get_arch("mixtral-8x7b")), dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, cfg.d_model))
    y_dense, aux_d = moe_mod.moe_dense_ref(params, x, cfg)
    y_cap, aux_c = moe_mod.moe_capacity(params, x, cfg)
    np.testing.assert_allclose(y_cap, y_dense, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(aux_c, aux_d, atol=1e-6)


def test_moe_capacity_drops_overflow_tokens():
    cfg = dataclasses.replace(reduced(get_arch("mixtral-8x7b")), dtype="float32")
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    y, _ = moe_mod.moe_capacity(params, x, cfg, capacity=1)
    assert jnp.isfinite(y).all()
    # with capacity 1 per expert most tokens are dropped -> smaller norm
    y_full, _ = moe_mod.moe_capacity(params, x, cfg, capacity=64)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), t=st.sampled_from([4, 16, 33]))
def test_property_moe_router_weights_normalised(seed, t):
    """PROPERTY: per-token selected router weights sum to 1."""
    cfg = dataclasses.replace(reduced(get_arch("phi3.5-moe-42b-a6.6b")), dtype="float32")
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, cfg.d_model))
    w, idx, _ = moe_mod._route(params, x, cfg)
    np.testing.assert_allclose(jnp.sum(w, -1), jnp.ones(t), atol=1e-5)
    assert int(idx.max()) < cfg.moe.num_experts


# ---------------------------------------------------------------------------
def test_swa_ring_buffer_matches_full_cache():
    """A sliding-window arch decoding with its ring buffer must match the
    same model decoding with sliding-window masking over a full cache."""
    cfg = dataclasses.replace(reduced(get_arch("h2o-danube-3-4b")), dtype="float32")
    cfg = dataclasses.replace(cfg, sliding_window=8, max_seq_len=64)
    cfg_full = dataclasses.replace(cfg, sliding_window=None)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    S = 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    logits_swa, _ = T.forward(params, cfg, {"tokens": toks})
    # manual full attention with window mask (oracle)
    logits_full, _ = T.forward(params, cfg_full, {"tokens": toks})
    # they differ (window matters) ...
    assert float(jnp.max(jnp.abs(logits_swa - logits_full))) > 1e-3
    # ... but SWA prefill+decode vs SWA forward agree (ring correctness)
    pre = {"tokens": toks[:, :-1]}
    _, caches = T.prefill(params, cfg, pre, seq_len=S + 4)
    lg, _ = T.decode_step(params, cfg, toks[:, -1:], jnp.int32(S - 1), caches)
    np.testing.assert_allclose(lg[:, 0], logits_swa[:, -1], atol=2e-2, rtol=2e-2)


def test_causal_mask_properties():
    m = causal_mask(6, 6)
    assert bool(m[0, 0]) and not bool(m[0, 1])
    assert m.sum() == 21
    mw = causal_mask(6, 6, window=2)
    assert not bool(mw[5, 3]) and bool(mw[5, 4]) and bool(mw[5, 5])
    mo = causal_mask(2, 6, q_offset=4)
    assert bool(mo[0, 4]) and not bool(mo[0, 5]) and bool(mo[1, 5])


def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 4, 2, 64))
    pos = jnp.arange(4)[None]
    y = apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), atol=1e-4)
    # relative property: <q_m, k_n> depends only on m-n
    q = jax.random.normal(key, (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    def score(m, n):
        qm = apply_rope(q, jnp.array([[m]]), 10_000.0)
        kn = apply_rope(k, jnp.array([[n]]), 10_000.0)
        return float(jnp.sum(qm * kn))
    assert score(3, 1) == pytest.approx(score(10, 8), abs=1e-3)
    assert score(3, 1) != pytest.approx(score(10, 5), abs=1e-3)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,expected_b", [
    ("mixtral-8x7b", 46.7), ("deepseek-67b", 67.4), ("qwen3-1.7b", 1.72),
    ("jamba-v0.1-52b", 51.6), ("phi3.5-moe-42b-a6.6b", 41.9),
])
def test_param_counts_match_published(arch, expected_b):
    from repro.models.flops import param_count
    n = param_count(get_arch(arch)) / 1e9
    assert n == pytest.approx(expected_b, rel=0.02), n


def test_active_params_match_model_cards():
    from repro.models.flops import active_param_count
    assert active_param_count(get_arch("phi3.5-moe-42b-a6.6b")) / 1e9 == pytest.approx(6.6, rel=0.05)
    assert active_param_count(get_arch("mixtral-8x7b")) / 1e9 == pytest.approx(12.9, rel=0.05)
    assert active_param_count(get_arch("jamba-v0.1-52b")) / 1e9 == pytest.approx(12.1, rel=0.05)


def test_chunked_attention_matches_dense():
    """The flash-style XLA attention (§Perf memory optimization) must be
    numerically identical to the dense oracle."""
    from repro.models.attention import chunked_gqa_attend, gqa_attend
    key = jax.random.PRNGKey(0)
    for (B, S, T_, Hq, Hkv, hd, win, cq) in [
            (2, 64, 64, 4, 2, 32, None, 16), (1, 96, 96, 8, 8, 64, 24, 32)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, Hq, hd))
        k = jax.random.normal(ks[1], (B, T_, Hkv, hd))
        v = jax.random.normal(ks[2], (B, T_, Hkv, hd))
        a = chunked_gqa_attend(q, k, v, causal=True, window=win, q_chunk=cq)
        b = gqa_attend(q, k, v, causal_mask(S, T_, window=win))
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_moe_local_dispatch_matches_oracle():
    """Per-shard local dispatch (§Perf 'moe_local') == dense oracle with
    ample capacity."""
    cfg = dataclasses.replace(reduced(get_arch("mixtral-8x7b")), dtype="float32")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    yd, _ = moe_mod.moe_dense_ref(params, x, cfg)
    yg, _ = moe_mod.moe_capacity_grouped(params, x, cfg, n_groups=4, capacity=32)
    np.testing.assert_allclose(yg, yd, atol=1e-4, rtol=1e-4)
