"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family (2 layers, d_model<=512, <=4 experts) runs one
forward + one train step on CPU; output shapes asserted, no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.config import get_arch, reduced
from repro.configs import ASSIGNED
from repro.models import transformer as T
from repro.models.frontends import stub_frontend_embeddings
from repro.train.losses import cross_entropy
from repro.train.optimizer import AdamWConfig, apply_updates, init_state

KEY = jax.random.PRNGKey(0)
B, S = 2, 16

MODEL_ARCHS = [a for a in ASSIGNED]


def _smoke_cfg(name):
    cfg = reduced(get_arch(name))
    return dataclasses.replace(cfg, dtype="float32")


def _batch(cfg, with_labels=False):
    batch = {}
    if cfg.frontend is not None and cfg.encdec is None:
        batch["embeds"] = jax.random.normal(KEY, (B, S, cfg.frontend.embed_dim), jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.encdec is not None:
        batch["enc_embeds"] = stub_frontend_embeddings(cfg, KEY, B)
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", MODEL_ARCHS)
def test_reduced_forward(arch):
    cfg = _smoke_cfg(arch)
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = T.init_params(cfg, KEY)
    logits, aux = T.forward(params, cfg, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", MODEL_ARCHS)
def test_reduced_train_step(arch):
    cfg = _smoke_cfg(arch)
    params = T.init_params(cfg, KEY)
    opt = init_state(params)
    batch = _batch(cfg, with_labels=True)

    def loss_fn(p):
        logits, aux = T.forward(p, cfg, batch)
        return cross_entropy(logits, batch["labels"])["loss"] + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params, opt, m = apply_updates(AdamWConfig(), params, grads, opt)
    assert jnp.isfinite(loss)
    assert jnp.isfinite(m["grad_norm"])
    # at least one parameter must actually move
    moved = any(
        not jnp.allclose(a, b)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", MODEL_ARCHS)
def test_reduced_prefill_decode_consistency(arch):
    """decode_step after prefill must reproduce the full-forward logits
    for the next position (the KV-cache correctness invariant)."""
    cfg = _smoke_cfg(arch)
    params = T.init_params(cfg, KEY)
    batch = _batch(cfg)
    # full forward over S tokens
    logits_full, _ = T.forward(params, cfg, batch, moe_mode="dense")
    # prefill S-1 tokens, then decode token S-1
    if "tokens" in batch:
        pre = dict(batch)
        pre["tokens"] = batch["tokens"][:, :-1]
        last_tok = batch["tokens"][:, -1:]
    else:  # VLM: embeds prompt — decode takes tokens, so skip strictness
        pytest.skip("decode consistency needs token inputs (VLM uses embeds)")
    lg_pre, caches = T.prefill(params, cfg, pre, seq_len=S + 2, moe_mode="dense")
    lg_dec, _ = T.decode_step(params, cfg, last_tok, jnp.int32(S - 1), caches,
                              moe_mode="dense")
    a = logits_full[:, -1]
    b = lg_dec[:, 0]
    assert jnp.max(jnp.abs(a - b)) < 2e-2, float(jnp.max(jnp.abs(a - b)))
    # prefill's own last logits must match forward at position S-2
    c = logits_full[:, -2]
    d = lg_pre[:, 0]
    assert jnp.max(jnp.abs(c - d)) < 2e-2, float(jnp.max(jnp.abs(c - d)))
