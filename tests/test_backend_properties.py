"""Property tests over the backend registry (hypothesis; skipped when it
is not installed, see _hypothesis_compat): every registered backend's
ColdStartModel has strictly positive timings, lifecycle scale cost is
monotone in the replica count, and LeadTimePolicy's derived control
period / desired replica count always land inside their clamp bands for
arbitrary cold-start models — not just the six shipped ones."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (ColdStartModel, FaasdRuntime, FunctionSpec,
                        LeadTimePolicy, QueueDepthPolicy, Simulator,
                        available_backends, get_backend_class)
from repro.core.backends import SnapshotColdStartModel

ALL_BACKENDS = available_backends()


def _drive(sim, gen):
    p = sim.process(gen)
    p.completion.callbacks.append(lambda _v: sim.stop())
    sim.run()
    assert p.done
    return p.result


# ---------------------------------------------------------------------------
# Registry-wide model invariants (always run; the registry is finite).


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_coldstart_timings_strictly_positive(name):
    cs = get_backend_class(name).coldstart
    assert cs.deploy_seconds > 0
    assert cs.scale_seconds > 0
    assert cs.query_seconds > 0
    if isinstance(cs, SnapshotColdStartModel):
        assert 0 < cs.restore_seconds < cs.deploy_seconds
        # the policy-visible scale cost is the restore path
        assert cs.scale_seconds == pytest.approx(cs.restore_seconds)


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_lead_time_period_in_band_for_every_registered_backend(name):
    pol = LeadTimePolicy()
    period = pol.control_period(get_backend_class(name).coldstart)
    assert pol.period_floor_s <= period <= pol.period_ceil_s


# ---------------------------------------------------------------------------
# Lifecycle property: scaling 1 -> n costs monotonically more sim time.


@settings(max_examples=30, deadline=None)
@given(
    name=st.sampled_from(ALL_BACKENDS),
    lo=st.integers(1, 6),
    extra=st.integers(0, 6),
    seed=st.integers(0, 1_000),
)
def test_property_scale_cost_monotone_in_replica_count(name, lo, extra, seed):
    """PROPERTY: for any backend, time(scale 1->lo) <= time(scale 1->hi)
    when lo <= hi — adding more replicas never gets cheaper (restores,
    uProc spawns and container tasks all cost >= 0 each)."""
    hi = lo + extra

    def scale_cost(replicas):
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=name)
        rt.deploy_blocking(FunctionSpec(name="f"))
        t0 = sim.now
        _drive(sim, rt.backend.scale("f", replicas))
        return sim.now - t0

    assert scale_cost(lo) <= scale_cost(hi) + 1e-12


# ---------------------------------------------------------------------------
# Policy properties for arbitrary cold-start models.


def _model(deploy_ms, scale_factor, query_ms):
    return ColdStartModel(deploy_ms=deploy_ms, scale_factor=scale_factor,
                          query_ms=query_ms)


@settings(max_examples=200, deadline=None)
@given(
    deploy_ms=st.floats(1e-3, 1e6, allow_nan=False, allow_infinity=False),
    scale_factor=st.floats(0.0, 10.0, allow_nan=False),
    floor=st.floats(1e-4, 1.0, allow_nan=False),
    ceil_mult=st.floats(1.0, 1e3, allow_nan=False),
    lead_mult=st.floats(0.1, 10.0, allow_nan=False),
)
def test_property_lead_time_period_always_inside_clamp_band(
        deploy_ms, scale_factor, floor, ceil_mult, lead_mult):
    """PROPERTY: the derived control period lands inside
    [period_floor_s, period_ceil_s] for ANY cold-start model — a backend
    can never drive the controller into a zero-period spin loop or an
    unbounded sampling interval."""
    ceil = floor * ceil_mult
    pol = LeadTimePolicy(period_floor_s=floor, period_ceil_s=ceil,
                         lead_mult=lead_mult)
    period = pol.control_period(_model(deploy_ms, scale_factor, 1.0))
    assert floor <= period <= ceil
    assert math.isfinite(period)


@settings(max_examples=200, deadline=None)
@given(
    deploy_ms=st.floats(1e-3, 1e6, allow_nan=False, allow_infinity=False),
    scale_factor=st.floats(0.0, 10.0, allow_nan=False),
    inflight=st.floats(0.0, 1e9, allow_nan=False),
    replicas=st.integers(0, 10_000),
    rate=st.floats(0.0, 1e6, allow_nan=False),
    min_replicas=st.integers(1, 8),
    extra=st.integers(0, 24),
    target=st.floats(0.1, 100.0, allow_nan=False),
)
def test_property_desired_replicas_always_clamped(
        deploy_ms, scale_factor, inflight, replicas, rate, min_replicas,
        extra, target):
    """PROPERTY: both policies' desired() stays inside
    [min_replicas, max_replicas] for arbitrary load signals and models."""
    cs = _model(deploy_ms, scale_factor, 1.0)
    max_replicas = min_replicas + extra
    for pol in (QueueDepthPolicy(min_replicas=min_replicas,
                                 max_replicas=max_replicas,
                                 target_inflight_per_replica=target),
                LeadTimePolicy(min_replicas=min_replicas,
                               max_replicas=max_replicas,
                               target_inflight_per_replica=target)):
        want = pol.desired(inflight=inflight, replicas=replicas,
                           arrival_rate_rps=rate, coldstart=cs)
        assert min_replicas <= want <= max_replicas
