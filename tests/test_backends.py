"""Execution-backend API tests: the registry, a parametrized conformance
suite every registered backend must pass (uniform deploy/scale/query/
remove lifecycle semantics, plus the snapshot-cache invariants for
backends that keep one), the fig5-style latency and cold-start orderings
across the 6-backend isolation spectrum, the runner on arbitrary backend
sets, and the artifact-compare / --list tooling.

The conformance suite parametrizes over ``available_backends()`` — the
live registry — so registering a 7th backend gets it lifecycle (and,
if it carries a ``snapshots`` cache, snapshot-contract) coverage with
zero test edits."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import (FaasdRuntime, FunctionSpec, PollingModel, Simulator,
                        UnknownFunctionError, available_backends,
                        get_backend_class, register_backend, run_sequential)
from repro.core.backends import (_REGISTRY, ColdStartModel,
                                 resolve_backend)
from repro.core.firecracker import SnapshotCache
from repro.core.gvisor import GVisor
from repro.experiments import (ExperimentRunner, build_artifact, get_scenario,
                               metric_row, validate_artifact, write_artifact)

ALL_BACKENDS = available_backends()
FOUR = ("containerd", "junctiond", "quark", "wasm")
# the full isolation spectrum, ordered by warm-path latency
SIX = ("junctiond", "wasm", "containerd", "firecracker", "gvisor", "quark")


def _drive(sim, gen):
    """Run one generator process to completion and return its result."""
    p = sim.process(gen)
    p.completion.callbacks.append(lambda _v: sim.stop())
    sim.run()
    assert p.done
    return p.result


def _runtime(backend, seed=0, **kw):
    sim = Simulator(seed=seed)
    return FaasdRuntime(sim, backend=backend, **kw)


# ---------------------------------------------------------------------------
# Registry.


def test_registry_contains_the_six_builtins():
    assert set(ALL_BACKENDS) >= set(SIX)


def test_unknown_backend_name_lists_registered():
    with pytest.raises(ValueError, match="containerd.*junctiond"):
        get_backend_class("bogus")
    with pytest.raises(ValueError, match="unknown backend"):
        FaasdRuntime(Simulator(), backend="bogus")


def test_register_backend_rejects_duplicate_and_unnamed():
    containerd = get_backend_class("containerd")
    with pytest.raises(ValueError, match="already registered"):
        register_backend(type("Fake", (containerd,), {"name": "containerd"}))
    with pytest.raises(ValueError, match="non-empty"):
        register_backend(type("Anon", (containerd,), {"name": ""}))
    assert _REGISTRY["containerd"] is containerd    # registry unharmed


def test_custom_backend_registers_and_serves_traffic():
    wasm = get_backend_class("wasm")

    @register_backend
    class TurboTest(wasm):
        name = "turbo-test"
        coldstart = ColdStartModel(deploy_ms=0.1, scale_factor=0.5,
                                   query_ms=0.05)

        def __init__(self, sim, *, n_cores=4, polling_model=None):
            super().__init__(sim, n_cores=n_cores)

    try:
        assert "turbo-test" in available_backends()
        rt = _runtime("turbo-test")
        # the class's own constructor default wins when resolved by name
        assert rt.cores.n_cores == 4
        rt.deploy_blocking(FunctionSpec(name="f"))
        s = run_sequential(rt, "f", n=5)
        assert s.n == 5 and s.median_ms > 0
    finally:
        _REGISTRY.pop("turbo-test", None)


def test_runtime_accepts_backend_instance():
    sim = Simulator(seed=0)
    be = get_backend_class("containerd")(sim, n_cores=8)
    rt = FaasdRuntime(sim, backend=be)
    assert rt.backend is be and rt.manager is be
    assert rt.backend_name == "containerd"
    assert rt.cores.n_cores == 8
    assert resolve_backend(be, sim) is be


def test_backend_instance_must_match_simulator_and_config():
    sim = Simulator(seed=0)
    be = get_backend_class("containerd")(sim, n_cores=8)
    # bound to a different simulator -> diagnosable error, not a hang
    with pytest.raises(ValueError, match="different Simulator"):
        FaasdRuntime(Simulator(seed=1), backend=be)
    # conflicting config alongside a ready instance -> rejected, not ignored
    with pytest.raises(ValueError, match="configure the instance"):
        FaasdRuntime(sim, backend=be, n_cores=36)
    with pytest.raises(ValueError, match="configure the instance"):
        resolve_backend(be, sim, polling_model=PollingModel.CENTRALIZED)


# ---------------------------------------------------------------------------
# Lifecycle conformance: every registered backend, same semantics.


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_backend_declares_its_bundle(name):
    cls = get_backend_class(name)
    assert cls.name == name
    assert cls.runtime.name and cls.stack_costs.name
    assert cls.coldstart.deploy_ms > 0
    assert cls.coldstart.query_ms > 0


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_lifecycle_deploy_query_scale_remove(name):
    rt = _runtime(name)
    be, sim = rt.backend, rt.sim
    sched_before = len(be.scheduler.instances) if be.scheduler else None
    assert be.lookup("aes") is None

    rt.deploy_blocking(FunctionSpec(name="aes", scale=2))
    rec = be.lookup("aes")
    assert rec is not None and rec.ready and rec.replicas == 2
    assert be.deploys == 1

    # control-plane query: same record, after the backend's RPC delay
    t0 = sim.now
    assert _drive(sim, be.query("aes")) is rec
    assert sim.now - t0 == pytest.approx(be.coldstart.query_seconds)

    # scale up then down; the record tracks the replica count
    _drive(sim, be.scale("aes", 5))
    assert be.lookup("aes").replicas == 5
    _drive(sim, be.scale("aes", 1))
    assert be.lookup("aes").replicas == 1

    # remove releases every resource: record gone, query says None,
    # scheduler-managed instances unregistered, and a redeploy works
    be.remove("aes")
    assert be.lookup("aes") is None
    assert _drive(sim, be.query("aes")) is None
    if sched_before is not None:
        assert len(be.scheduler.instances) == sched_before
    be.remove("aes")                      # idempotent teardown
    rt.deploy_blocking(FunctionSpec(name="aes"))
    assert be.lookup("aes").ready


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_redeploy_releases_old_resources(name):
    """Deploying an existing name again must release the first
    deployment's resources (scheduler registrations, reserved cores),
    exactly as remove would — no leaks on config updates."""
    rt = _runtime(name)
    be = rt.backend
    sched_before = len(be.scheduler.instances) if be.scheduler else None
    rt.deploy_blocking(FunctionSpec(name="aes"))
    rt.deploy_blocking(FunctionSpec(name="aes", scale=2))
    assert be.deploys == 2
    assert be.lookup("aes").replicas == 2
    if sched_before is not None:
        assert len(be.scheduler.instances) == sched_before + 1


def test_junctiond_scale_to_zero_keeps_one_warm_uproc():
    """Scale-to-zero semantics match the isolated path: the record says
    zero replicas but one warm uProc stays behind."""
    rt = _runtime("junctiond")
    be = rt.backend
    rt.deploy_blocking(FunctionSpec(name="aes", scale=3))
    _drive(rt.sim, be.scale("aes", 0))
    rec = be.lookup("aes")
    assert rec.replicas == 0
    assert len(rec.instances[0].uprocs) == 1 and rec.ready


def test_junctiond_isolated_scale_reaps_sibling_instances():
    """Scale on an isolate_replicas deployment adjusts the *instance*
    count — including releasing scheduler registrations on the way down
    (the lifecycle asymmetry the conformance work exists to prevent)."""
    rt = _runtime("junctiond")
    be, sim = rt.backend, rt.sim
    base = len(be.scheduler.instances)
    _drive(sim, be.deploy("iso", scale=4, isolate_replicas=True))
    rec = be.lookup("iso")
    assert rec.isolated and len(rec.instances) == 4
    assert len(be.scheduler.instances) == base + 4

    t0 = sim.now
    _drive(sim, be.scale("iso", 1))
    assert rec.replicas == 1 and len(rec.instances) == 1
    assert len(be.scheduler.instances) == base + 1
    assert sim.now == t0                      # reaping costs no init time

    t0 = sim.now
    _drive(sim, be.scale("iso", 3))           # back up: full instance inits
    assert len(rec.instances) == 3 and rec.ready
    assert len(be.scheduler.instances) == base + 3
    assert sim.now - t0 == pytest.approx(2 * be.coldstart.deploy_seconds)

    be.remove("iso")
    assert len(be.scheduler.instances) == base


# ---------------------------------------------------------------------------
# Snapshot-cache lifecycle contract: conformance for every registered
# backend that keeps a per-function snapshot cache (today: firecracker).
# Invariants: deploy warms the snapshot, a redeploy restores from it
# (second cold start strictly cheaper than the first), remove evicts it
# (the next deploy pays a full boot again and re-warms it).


def _snapshotting(name):
    rt = _runtime(name)
    if not hasattr(rt.backend, "snapshots"):
        pytest.skip(f"{name} keeps no snapshot cache")
    return rt


def _deploy_s(rt, fn="aes", **kw):
    t0 = rt.sim.now
    rt.deploy_blocking(FunctionSpec(name=fn, **kw))
    return rt.sim.now - t0


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_snapshot_deploy_warms_and_second_cold_start_is_cheaper(name):
    rt = _snapshotting(name)
    be = rt.backend
    assert "aes" not in be.snapshots
    first = _deploy_s(rt)
    assert "aes" in be.snapshots        # deploy warmed the snapshot
    second = _deploy_s(rt)              # redeploy restores from it
    assert second < first
    assert be.lookup("aes").ready


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_snapshot_remove_evicts_and_redeploy_rewarms(name):
    rt = _snapshotting(name)
    be = rt.backend
    first = _deploy_s(rt)
    be.remove("aes")
    assert "aes" not in be.snapshots    # remove evicts the snapshot
    assert be.lookup("aes") is None
    again = _deploy_s(rt)               # full boot again, snapshot re-warmed
    assert again == pytest.approx(first)
    assert "aes" in be.snapshots
    second = _deploy_s(rt)
    assert second < again


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_snapshot_boot_pays_the_save_charge(name):
    """Warming the snapshot cache is not free: the first boot pays
    deploy + save (boot_seconds), so boot >= boot-without-save
    (deploy_seconds) always, strictly when the model charges a save."""
    rt = _snapshotting(name)
    cs = rt.backend.coldstart
    first = _deploy_s(rt)
    assert first == pytest.approx(cs.boot_seconds)
    assert cs.boot_seconds >= cs.deploy_seconds   # boot >= boot-without-save
    if cs.save_ms > 0:
        assert first > cs.deploy_seconds
        assert first == pytest.approx(cs.deploy_seconds + cs.save_seconds)
    # the save charge lives on the boot path only: restores skip it
    second = _deploy_s(rt)
    assert second == pytest.approx(cs.restore_seconds)


def test_firecracker_restore_is_an_order_faster_than_boot():
    rt = _runtime("firecracker")
    boot = _deploy_s(rt)
    restore = _deploy_s(rt)
    assert boot / restore >= 10         # ~125 ms boot vs ~5 ms restore
    assert rt.backend.boots == 1 and rt.backend.restores == 1
    assert rt.backend.lookup("aes").restored


def test_firecracker_scale_up_restores_from_the_snapshot():
    rt = _runtime("firecracker")
    be, sim = rt.backend, rt.sim
    _deploy_s(rt)
    t0 = sim.now
    _drive(sim, be.scale("aes", 4))     # 3 new replicas, all restores
    assert sim.now - t0 == pytest.approx(3 * be.coldstart.restore_seconds)
    assert be.lookup("aes").replicas == 4
    t0 = sim.now
    _drive(sim, be.scale("aes", 1))     # reaping microVMs costs no init
    assert sim.now == t0
    assert be.lookup("aes").replicas == 1


def test_firecracker_snapshot_cache_capacity_evicts_lru():
    sim = Simulator(seed=0)
    be = get_backend_class("firecracker")(sim, snapshot_capacity=2)
    rt = FaasdRuntime(sim, backend=be)
    rt.deploy_blocking(FunctionSpec(name="a"))
    rt.deploy_blocking(FunctionSpec(name="b"))
    # touch a so b is the least recently used snapshot
    assert be.snapshots.get("a") is not None
    rt.deploy_blocking(FunctionSpec(name="c"))      # capacity 2: evicts b
    assert "a" in be.snapshots and "c" in be.snapshots
    assert "b" not in be.snapshots
    assert be.snapshots.evictions == 1
    # scaling b up after its snapshot was evicted re-boots (re-warming the
    # cache, save charge included) instead of restoring from a snapshot
    # that no longer exists
    t0 = sim.now
    _drive(sim, be.scale("b", 2))
    assert sim.now - t0 == pytest.approx(be.coldstart.boot_seconds)
    assert "b" in be.snapshots


def test_snapshot_cache_rejects_nonpositive_capacity():
    with pytest.raises(ValueError, match="capacity"):
        SnapshotCache(0)


def test_snapshot_coldstart_model_derives_scale_from_restore():
    """scale_seconds and scale_factor are both derived from the restore
    path (a scale-up never pays a full boot), so a caller cannot
    desynchronise the marginal replica cost from restore_ms; nonsensical
    restore timings fail at construction."""
    from repro.core import SnapshotColdStartModel
    m = SnapshotColdStartModel(deploy_ms=100.0, query_ms=1.0, restore_ms=4.0)
    assert m.scale_seconds == pytest.approx(m.restore_seconds) == 0.004
    assert m.scale_factor == pytest.approx(0.04)
    # an explicit (stale) scale_factor is overridden, never trusted
    stale = SnapshotColdStartModel(deploy_ms=100.0, query_ms=1.0,
                                   restore_ms=4.0, scale_factor=0.6)
    assert stale.scale_factor == pytest.approx(0.04)
    with pytest.raises(ValueError, match="restore_ms"):
        SnapshotColdStartModel(deploy_ms=100.0, query_ms=1.0)  # unset
    with pytest.raises(ValueError, match="restore_ms"):
        SnapshotColdStartModel(deploy_ms=100.0, query_ms=1.0,
                               restore_ms=200.0)


def test_gvisor_platform_knob_selects_cost_tables():
    """The KVM platform (the registered default) is measurably faster on
    the warm path than ptrace; both share the lifecycle and cold-start
    class, and an unknown platform fails loudly."""
    def median(platform):
        sim = Simulator(seed=0)
        be = GVisor(sim, platform=platform)
        rt = FaasdRuntime(sim, backend=be)
        rt.deploy_blocking(FunctionSpec(name="aes"))
        return run_sequential(rt, "aes", n=40).median_ms

    assert median("kvm") < median("ptrace")
    assert GVisor(Simulator(), platform="ptrace").runtime.name == "gvisor-ptrace"
    # resolved by name, the registry default is the KVM tables
    assert _runtime("gvisor").backend.runtime.name == "gvisor-kvm"
    with pytest.raises(ValueError, match="unknown gVisor platform"):
        GVisor(Simulator(), platform="hyperv")


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_scale_on_undeployed_raises_uniformly(name):
    rt = _runtime(name)
    with pytest.raises(UnknownFunctionError, match="ghost"):
        _drive(rt.sim, rt.backend.scale("ghost", 2))


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_warm_invocations_complete_with_ordered_timestamps(name):
    rt = _runtime(name)
    rt.deploy_blocking(FunctionSpec(name="aes"))
    run_sequential(rt, "aes", n=5)
    assert len(rt.records) == 5
    for r in rt.records:
        assert r.t_done > r.t_end_exec > r.t_start_exec > r.t_arrival


# ---------------------------------------------------------------------------
# Cross-backend orderings (the fig5-style sanity matrix).


def _fig5_median_ms(name, seeds=range(3), n=60):
    meds = []
    for seed in seeds:
        rt = _runtime(name, seed=seed)
        rt.deploy_blocking(FunctionSpec(name="aes"))
        meds.append(run_sequential(rt, "aes", n=n).median_ms)
    return float(np.mean(meds))


def test_fig5_style_warm_latency_ordering():
    """Warm e2e medians follow the modeled datapaths across the whole
    spectrum: kernel-bypass (junctiond) fastest, lightweight wasm beats
    containers, the microVM's virtio double-stack sits just above plain
    containers, gVisor's Sentry interception above that, and quark's full
    guest-kernel tax makes it the slowest."""
    med = {b: _fig5_median_ms(b) for b in SIX}
    assert (med["junctiond"] < med["wasm"] < med["containerd"]
            < med["firecracker"] < med["gvisor"] < med["quark"])


def test_coldstart_ordering_across_backends():
    """First cold starts follow the modeled classes: sub-ms wasm
    instantiate, paper-measured 3.4 ms Junction init, the microVM's full
    boot, gVisor's Sentry bring-up (no guest Linux), container-class
    containerd, and quark's extra guest-kernel boot on top.  The
    firecracker *restore* path slots between junctiond and gvisor —
    that's the gap the snapshot cache buys."""
    def cold_s(name):
        rt = _runtime(name)
        t0 = rt.sim.now
        rt.deploy_blocking(FunctionSpec(name="f"))
        return rt.sim.now - t0

    cold = {b: cold_s(b) for b in SIX}
    assert cold["wasm"] < 1e-3                       # sub-ms instantiate
    assert (cold["wasm"] < cold["junctiond"] < cold["firecracker"]
            < cold["gvisor"] < cold["containerd"] < cold["quark"])
    assert cold["containerd"] / cold["junctiond"] > 50
    restore = get_backend_class("firecracker").coldstart.restore_seconds
    assert cold["junctiond"] < restore < cold["gvisor"]


# ---------------------------------------------------------------------------
# Experiments layer over arbitrary backend sets.


def test_runner_four_backend_matrix_keeps_pair_claims(tmp_path):
    sc = dataclasses.replace(get_scenario("paper-fig5"), seeds=(0,),
                             n_requests=25, backends=FOUR)
    doc = ExperimentRunner(smoke=True).run_suite([sc], suite="unit")
    validate_artifact(doc)
    entry = doc["scenarios"][0]
    assert set(entry["backends"]) == set(FOUR)
    assert entry["backend_set"] == sorted(FOUR)
    assert entry["claims_pair"] == ["containerd", "junctiond"]
    # paper-claim deltas still come from the baseline/treatment pair
    assert "e2e_median_reduction_pct" in entry["claims"]
    names = {m["name"] for m in doc["metrics"]}
    assert "fig5_median_reduction" in names
    for b in FOUR:                       # every backend lands in the flat table
        assert f"scn_paper-fig5_{b}_median" in names
    path = tmp_path / "BENCH_matrix.json"
    write_artifact(str(path), doc)
    validate_artifact(json.loads(path.read_text()))


def test_storm_measures_snapshot_restore_vs_full_boot():
    """The cold-start storm runs a redeploy wave: plain backends pay the
    same cold start again (speedup ~1x), firecracker restores from the
    snapshots the first wave warmed (>= 10x)."""
    sc = dataclasses.replace(get_scenario("cold-start-storm"), seeds=(0,),
                             storm_functions=4,
                             backends=("containerd", "firecracker"))
    doc = ExperimentRunner(smoke=True).run_suite([sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    res = doc["scenarios"][0]["backends"]
    for b, r in res.items():
        assert r["redeploy_median_ms"] > 0
        assert r["single_redeploy_ms"] > 0
    assert res["containerd"]["redeploy_speedup"] == pytest.approx(1.0)
    assert res["firecracker"]["redeploy_speedup"] >= 10
    assert res["firecracker"]["single_redeploy_ms"] < \
        res["containerd"]["single_redeploy_ms"]
    names = {m["name"]: m["value"] for m in doc["metrics"]}
    assert names["scn_cold-start-storm_firecracker_redeploy_speedup"] >= 10
    assert names["scn_cold-start-storm_containerd_redeploy_speedup"] == \
        pytest.approx(1.0)


def test_runner_skips_claims_without_the_pair():
    sc = dataclasses.replace(get_scenario("paper-fig5"), seeds=(0,),
                             n_requests=20, backends=("quark", "wasm"))
    doc = ExperimentRunner(smoke=True).run_suite([sc], suite="unit")
    validate_artifact(doc)
    entry = doc["scenarios"][0]
    assert set(entry["backends"]) == {"quark", "wasm"}
    assert "claims" not in entry
    assert all(not m["name"].startswith("fig5_") for m in doc["metrics"])


def test_open_mode_fails_loudly_without_a_rate_grid():
    """A *grid-mode* scenario (explicit ``rates``) run against a backend
    with neither an explicit grid nor a '*' fallback must fail its cell
    (caught in the artifact's failures) rather than emit a zero-sample
    result with NaN medians.  Search-mode scenarios never hit this: any
    backend can be searched."""
    anchor = get_scenario("multi-tenant-mix")       # the pinned-grid anchor
    rates = {b: g for b, g in anchor.rates.items() if b != "*"}
    sc = dataclasses.replace(anchor, rates=rates, smoke_rates=None,
                             backends=("containerd", "turbo"))
    assert sc.search_spec() is None                 # still grid mode
    doc = ExperimentRunner(smoke=True).run_suite([sc], suite="unit")
    assert any(f["backend"] == "turbo" and "rate grid" in f["error"]
               for f in doc["failures"])


def test_validate_artifact_accepts_v1_and_v2_schemas():
    """Artifacts written by older commits (schema_version 1/2) must keep
    validating — they are compare.py baselines."""
    v1 = build_artifact("old", [{"name": "s", "mode": "closed",
                                 "description": "d", "backends": {}}],
                        [metric_row("m", 1.0, "d")], [])
    v1["schema_version"] = 1
    validate_artifact(v1)                      # no backend_set required
    v2 = dict(v1, schema_version=2)
    v2["scenarios"] = [dict(v1["scenarios"][0], backend_set=["containerd"])]
    validate_artifact(v2)
    v3 = dict(v2, schema_version=3)
    validate_artifact(v3)
    v4 = dict(v2, schema_version=4)
    validate_artifact(v4)
    v5 = dict(v2, schema_version=5)
    validate_artifact(v5)
    v6 = dict(v2, schema_version=6)
    validate_artifact(v6)
    v7 = dict(v1, schema_version=7)
    with pytest.raises(ValueError, match="schema_version"):
        validate_artifact(v7)


def test_rates_fall_back_to_wildcard_grid_with_warning():
    """The '*' fallback still works for unknown backends, but it is no
    longer silent when the scenario carries explicit per-backend grids —
    the warning names the backend that fell through (the PR 3 failure
    mode was quark silently sweeping past its knee on the containerd
    grid)."""
    sc = get_scenario("multi-tenant-mix")
    assert sc.rates_for("junctiond") == (1500.0, 4000.0, 8000.0)
    with pytest.warns(RuntimeWarning, match="some-new-backend"):
        assert sc.rates_for("some-new-backend") == sc.rates["*"]
    with pytest.warns(RuntimeWarning, match="multi-tenant-mix"):
        assert sc.rates_for("some-new-backend", smoke=True) == \
            sc.smoke_rates["*"]
    # fig6 carries no grids at all any more: the adaptive search is its
    # default, for every backend including unregistered future ones
    fig6 = get_scenario("paper-fig6")
    assert fig6.rates is None and fig6.search_spec() is not None


def test_wildcard_only_grid_stays_silent():
    """trace-replay's rate table is {'*': ...} by design (the trace fixes
    the rate); a deliberate one-grid-for-all must not warn."""
    import warnings as _warnings
    sc = get_scenario("trace-replay")
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        for b in SIX:
            assert sc.rates_for(b) == (0.0,)


@pytest.mark.parametrize("scenario", ["multi-tenant-mix", "mixed-cold-warm"])
def test_grid_scenarios_keep_knee_sized_backend_grids(scenario):
    """The two scenarios that still carry rate tables (the pinned-grid
    regression anchor and the mixed mode's warm rate) keep explicit
    per-backend entries sized to the measured knees instead of riding the
    '*' fallback (which reuses the containerd grid and often sits past
    quark's knee)."""
    sc = get_scenario(scenario)
    for b in ("quark", "wasm", "firecracker", "gvisor"):
        assert b in sc.rates, f"{scenario} missing explicit {b} grid"
        assert sc.rates_for(b) != sc.rates["*"]
        if sc.smoke_rates:
            assert b in sc.smoke_rates
    containerd = sc.rates_for("containerd")
    # interception/virtio taxes put every sandboxed knee at or below
    # containerd's on the same workload, with quark lowest of the four
    for b in ("quark", "firecracker", "gvisor"):
        assert max(sc.rates_for(b)) <= max(containerd)
        assert min(sc.rates_for(b)) <= min(containerd)
    assert max(sc.rates_for("quark")) < max(containerd)
    assert max(sc.rates_for("gvisor")) <= max(sc.rates_for("firecracker"))


@pytest.mark.parametrize("scenario", ["paper-fig6", "bursty-burst",
                                      "diurnal-drift", "heavy-tail-mix",
                                      "autoscale-burst", "autoscale-diurnal"])
def test_open_scenarios_default_to_adaptive_search(scenario):
    """Every open-mode scenario except the pinned-grid anchor dropped its
    hand-sized six-backend grids: the adaptive knee search is the
    default, so registering backend #7 needs zero grid measurement."""
    sc = get_scenario(scenario)
    assert sc.rates is None and sc.smoke_rates is None
    spec = sc.search_spec()
    assert spec is not None
    assert spec.max_probes_for(smoke=True) <= spec.max_probes_for(False)
    assert spec.rel_tol_for(smoke=True) >= spec.rel_tol_for(False)
    # trace replay stays grid-shaped by design: the trace fixes the rate
    assert get_scenario("trace-replay").search_spec() is None


# ---------------------------------------------------------------------------
# benchmarks/compare.py: artifact diffing for CI.


def _metrics_doc(**values):
    return build_artifact("unit", [], [metric_row(k, v, "d")
                                       for k, v in values.items()], [])


def test_compare_flags_regressions_in_both_directions():
    from benchmarks.compare import compare_metrics, regressions
    old = _metrics_doc(fig5_junctiond_median=500.0, fig6_throughput_ratio=10.0,
                       coldstart_ratio=130.0)
    new = _metrics_doc(fig5_junctiond_median=700.0, fig6_throughput_ratio=4.0,
                       coldstart_ratio=131.0)
    rows, new_only = compare_metrics(old, new, threshold=0.10)
    by = {r["name"]: r for r in rows}
    assert by["fig5_junctiond_median"]["status"] == "regressed"   # latency up
    assert by["fig6_throughput_ratio"]["status"] == "regressed"   # ratio down
    assert by["coldstart_ratio"]["status"] == "ok"                # within noise
    assert not new_only
    assert {r["name"] for r in regressions(rows)} == {
        "fig5_junctiond_median", "fig6_throughput_ratio"}


def test_compare_sim_throughput_is_higher_is_better():
    # the event-heap driver's raw-speed gate: a drop in simulated
    # requests per wall-second must read as a regression, never as an
    # improved "latency"
    from benchmarks.compare import _direction, compare_metrics
    assert _direction("sim_throughput") == "higher"
    assert _direction("sim_throughput_speedup") == "higher"
    old = _metrics_doc(sim_throughput=47000.0, sim_throughput_speedup=20.0)
    new = _metrics_doc(sim_throughput=20000.0, sim_throughput_speedup=25.0)
    rows, _ = compare_metrics(old, new, threshold=0.10)
    by = {r["name"]: r for r in rows}
    assert by["sim_throughput"]["status"] == "regressed"
    assert by["sim_throughput_speedup"]["status"] == "improved"


def test_compare_improvements_and_new_metrics_are_not_regressions():
    from benchmarks.compare import compare_metrics, regressions
    old = _metrics_doc(fig5_junctiond_median=500.0)
    new = _metrics_doc(fig5_junctiond_median=300.0, extra_metric=1.0)
    rows, new_only = compare_metrics(old, new)
    assert rows[0]["status"] == "improved"
    assert new_only == ["extra_metric"]
    assert not regressions(rows)


def test_compare_missing_and_nan_metrics_regress():
    from benchmarks.compare import compare_metrics, regressions
    old = _metrics_doc(kept=1.0, dropped=2.0, lost_value=3.0)
    new = _metrics_doc(kept=1.0, lost_value=float("nan"))
    rows, _ = compare_metrics(old, new)
    by = {r["name"]: r for r in rows}
    assert by["dropped"]["status"] == "missing"
    assert by["lost_value"]["status"] == "nan"     # value became null
    assert len(regressions(rows)) == 2


def test_compare_cli_exit_codes(tmp_path):
    from benchmarks.compare import main
    old = tmp_path / "old.json"
    good = tmp_path / "good.json"
    bad = tmp_path / "bad.json"
    write_artifact(str(old), _metrics_doc(fig6_throughput_ratio=10.0))
    write_artifact(str(good), _metrics_doc(fig6_throughput_ratio=9.8))
    write_artifact(str(bad), _metrics_doc(fig6_throughput_ratio=3.0))
    assert main([str(old), str(good)]) == 0
    assert main([str(old), str(bad), "--threshold", "0.2"]) == 1


def test_compare_v3_artifact_against_v2_baseline(tmp_path):
    """Regression: a schema-v2 baseline (pre-autoscaler commits) must diff
    cleanly against a v3 candidate, and the direction-aware threshold must
    treat ``autoscale_reaction_ratio`` as higher-is-better — a ratio
    *drop* beyond the threshold regresses, a rise is an improvement."""
    from benchmarks.compare import compare_metrics, main, regressions

    def doc(version, **values):
        d = build_artifact("unit", [{"name": "s", "mode": "open",
                                     "description": "d",
                                     "backend_set": ["containerd"],
                                     "backends": {"containerd": {}}}],
                           [metric_row(k, v, "d") for k, v in values.items()],
                           [])
        d["schema_version"] = version
        validate_artifact(d)
        return d

    v2 = doc(2, autoscale_reaction_ratio=40.0, scn_s_containerd_median=900.0)
    # ratio halves (regression despite "going down" being good for the
    # latency metric next to it), latency improves
    worse = doc(3, autoscale_reaction_ratio=20.0,
                scn_s_containerd_median=700.0)
    rows, new_only = compare_metrics(v2, worse, threshold=0.10)
    by = {r["name"]: r for r in rows}
    assert by["autoscale_reaction_ratio"]["status"] == "regressed"
    assert by["autoscale_reaction_ratio"]["direction"] == "higher"
    assert by["scn_s_containerd_median"]["status"] == "improved"
    assert {r["name"] for r in regressions(rows)} == \
        {"autoscale_reaction_ratio"}
    assert not new_only
    # ratio rises within/beyond threshold: never a regression
    better = doc(3, autoscale_reaction_ratio=55.0,
                 scn_s_containerd_median=900.0)
    rows, _ = compare_metrics(v2, better, threshold=0.10)
    assert not regressions(rows)
    # end to end through the CLI, v2 file as the baseline
    old_p, bad_p, good_p = (tmp_path / n for n in
                            ("v2.json", "bad.json", "good.json"))
    write_artifact(str(old_p), v2)
    write_artifact(str(bad_p), worse)
    write_artifact(str(good_p), better)
    assert main([str(old_p), str(bad_p)]) == 1
    assert main([str(old_p), str(good_p)]) == 0


# ---------------------------------------------------------------------------
# benchmarks/run.py --list: enumeration without execution.


def test_run_list_enumerates_backends_and_scenarios(capsys):
    from benchmarks.run import main
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    for b in FOUR:
        assert b in out
    assert "paper-fig6" in out and "rates[" in out
    # --list distinguishes searched scenarios from pinned-grid ones
    assert "load=search" in out and "load=grid" in out
    assert "search: rel_tol=" in out
    assert "smoke" in out


def test_run_rejects_unknown_backends_flag(capsys):
    from benchmarks.run import main
    with pytest.raises(SystemExit):
        main(["--suite", "smoke", "--backends", "containerd,nope"])


def test_parse_backends_dedupes_preserving_order():
    from benchmarks.run import _parse_backends
    assert _parse_backends("junctiond,containerd, junctiond ,containerd") == \
        ("junctiond", "containerd")
