"""Config registry, reduced-variant contract, sharding rule engine, and
HLO collective parser units (no 512-device init needed here)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo_parse import parse_collectives
from repro.config import ALL_SHAPES, StepKind, get_arch, list_archs, reduced
from repro.configs import ASSIGNED
from repro.distributed import sharding as sh
from repro.models import transformer as T


def test_registry_has_all_assigned_archs():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs
    assert "paper-aes-600b" in archs
    assert len(ASSIGNED) == 10


def test_all_configs_cite_sources():
    for a in ASSIGNED:
        assert get_arch(a).citation, a


def test_assigned_shapes():
    names = [s.name for s in ALL_SHAPES]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    by = {s.name: s for s in ALL_SHAPES}
    assert by["train_4k"].step == StepKind.TRAIN
    assert by["decode_32k"].step == StepKind.DECODE
    assert by["long_500k"].global_batch == 1
    assert by["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_contract(arch):
    r = reduced(get_arch(arch))
    assert r.n_layers == 2
    assert r.d_model <= 512
    if r.moe:
        assert r.moe.num_experts <= 4
    r.validate()


def test_exact_assigned_hyperparams():
    m = get_arch("mixtral-8x7b")
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff,
            m.vocab_size) == (32, 4096, 32, 8, 14336, 32000)
    assert m.moe.num_experts == 8 and m.moe.top_k == 2 and m.sliding_window
    d = get_arch("deepseek-67b")
    assert (d.n_layers, d.d_model, d.n_heads, d.n_kv_heads) == (95, 8192, 64, 8)
    j = get_arch("jamba-v0.1-52b")
    kinds = j.block_kinds()
    # 1 attention block per 8, MoE every other block
    assert sum(1 for k in kinds if k.value.startswith("attn")) == 4
    assert sum(1 for k in kinds if "moe" in k.value) == 16
    r = get_arch("rwkv6-1.6b")
    assert r.is_attention_free and r.supports_long_context_natively


# ---------------------------------------------------------------------------
class _Mesh16:
    """Duck-typed 16x16 mesh for spec computation (no devices needed)."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_param_specs_divisibility_safe():
    """Every emitted spec must divide its dim (the engine's core contract),
    checked on real eval_shape trees for all archs."""
    for arch in ASSIGNED:
        cfg = get_arch(arch)
        shapes = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                jax.random.PRNGKey(0))
        specs = sh.param_specs(cfg, _Mesh16(), training=True)
        flat_s, _ = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_l = jax.tree_util.tree_leaves(shapes)
        assert len(flat_s) == len(flat_l)
        for spec, leaf in zip(flat_s, flat_l):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                n = 1
                for a in ((ax,) if isinstance(ax, str) else ax):
                    n *= 16
                assert dim % n == 0, (arch, leaf.shape, spec)


def test_seamless_vocab_fallback():
    """vocab 256206 is not divisible by 16 -> lm_head must NOT shard vocab."""
    cfg = get_arch("seamless-m4t-large-v2")
    specs = sh.param_specs(cfg, _Mesh16(), training=False)
    lm = specs["lm_head"]
    assert tuple(lm) != (None, "model")


def test_cache_specs_long_context_batch1():
    """long_500k (batch=1): the sequence dim must absorb the dp axes."""
    cfg = get_arch("h2o-danube-3-4b")   # SWA, cap = 4096
    tree = jax.eval_shape(lambda: T.init_caches(None, cfg, 1, 524_288))
    specs = sh.cache_specs_for({"layers": tree}, cfg, _Mesh16(), batch=1)
    k_spec = specs["layers"][0]["k"]
    assert k_spec[1] is None            # batch unshardable
    seq_axes = k_spec[2]
    assert seq_axes is not None         # dp landed on the sequence dim
    flat = (seq_axes,) if isinstance(seq_axes, str) else tuple(seq_axes)
    assert "data" in flat


# ---------------------------------------------------------------------------
def test_hlo_collective_parser():
    txt = """
  %ag = bf16[16,1024]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[256]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%sum
  %cp = bf16[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1}}
  %noise = f32[4]{0} add(%a, %b)
"""
    stats = parse_collectives(txt)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1,
                            "collective-permute": 1}
    ag = 16 * 1024 * 2 * 3 / 4
    ar = 256 * 4 * 2 * 0.5
    cp = 8 * 8 * 2
    assert stats.bytes_per_chip == pytest.approx(ag + ar + cp)
