"""Optional-hypothesis shim: ``from _hypothesis_compat import given,
settings, st`` works with or without hypothesis installed (it is a dev
extra, see requirements-dev.txt).  Without it, ``@given``-decorated
property tests collect as skipped and the rest of the module still runs.
"""
import pytest

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(**_kw):
        return lambda fn: fn

    def given(*_a, **_kw):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed "
                                     "(pip install -r requirements-dev.txt)")
            def skipped():
                pass
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco
