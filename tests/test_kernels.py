"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle,
across shapes and dtypes, as the deliverable requires."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.aes_ctr import aes_ctr
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.moe_gmm import moe_gmm
from repro.kernels.rwkv6_scan import rwkv6_scan

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 else dict(atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,T,d,causal,win", [
    (2, 4, 2, 128, 128, 64, True, None),
    (1, 8, 2, 96, 96, 64, True, 32),       # SWA + padding
    (2, 2, 2, 64, 192, 32, True, None),    # prefix-cache offset
    (1, 4, 4, 128, 128, 128, False, None), # bidirectional MHA
    (1, 2, 1, 257, 257, 64, True, None),   # odd lengths
])
def test_flash_attention(B, Hq, Hkv, S, T, d, causal, win, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, d), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, d), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=win,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,T,d", [
    (2, 8, 2, 300, 64), (1, 4, 4, 512, 128), (3, 16, 8, 257, 64),
])
def test_decode_attention(B, Hq, Hkv, T, d, dtype):
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, Hq, d), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, d), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, d), dtype)
    valid = jax.random.bernoulli(ks[3], 0.8, (B, T)).at[:, 0].set(True)
    out = decode_attention(q, k, v, valid, block_k=128, interpret=True)
    expect = ref.decode_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,S,di,ds,bd,bs", [
    (2, 128, 64, 16, 32, 64), (1, 256, 128, 8, 128, 128), (2, 64, 32, 4, 32, 32),
])
def test_mamba_scan(B, S, di, ds, bd, bs):
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, di))) * 0.1
    dtx = jax.random.normal(ks[1], (B, S, di)) * 0.1
    Bm = jax.random.normal(ks[2], (B, S, ds))
    Cm = jax.random.normal(ks[3], (B, S, ds))
    A = -jnp.exp(jax.random.normal(ks[4], (di, ds)))
    y, h = mamba_scan(dt, dtx, Bm, Cm, A, block_d=bd, block_s=bs, interpret=True)
    yr, hr = ref.mamba_scan_ref(dt, dtx, Bm, Cm, A)
    np.testing.assert_allclose(y, yr, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h, hr, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("B,T,H,hd,bt", [
    (2, 128, 2, 64, 32), (1, 64, 4, 32, 64), (2, 96, 1, 16, 48),
])
def test_rwkv6_scan(B, T, H, hd, bt):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, T, H, hd)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd)) * 0.5 + 2)
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    o, S = rwkv6_scan(r, k, v, w, u, block_t=bt, interpret=True)
    orf, Sr = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(o, orf, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(S, Sr, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F", [(4, 100, 96, 130), (2, 64, 256, 64), (8, 33, 48, 72)])
def test_moe_gmm(E, C, D, F, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    y = moe_gmm(x, w, block_c=64, block_f=64, block_d=64, interpret=True)
    expect = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(expect, np.float32),
                               atol=(5e-1 if dtype == jnp.bfloat16 else 2e-3),
                               rtol=(5e-2 if dtype == jnp.bfloat16 else 2e-4))


# ---------------------------------------------------------------------------
def test_aes_fips197_vector():
    """FIPS-197 appendix C.1 known-answer test."""
    key = jnp.arange(16, dtype=jnp.int32)
    pt = jnp.asarray([0x00, 0x11, 0x22, 0x33, 0x44, 0x55, 0x66, 0x77,
                      0x88, 0x99, 0xaa, 0xbb, 0xcc, 0xdd, 0xee, 0xff], jnp.int32)
    ct = ref.aes_encrypt_block_ref(pt, ref.aes_key_expand(key))
    expect = [0x69, 0xc4, 0xe0, 0xd8, 0x6a, 0x7b, 0x04, 0x30,
              0xd8, 0xcd, 0xb7, 0x80, 0x70, 0xb4, 0xc5, 0x5a]
    assert list(map(int, ct)) == expect


@pytest.mark.parametrize("n_blocks", [1, 38, 40])   # 600B = 38 blocks
def test_aes_ctr_kernel(n_blocks):
    key_bytes = jnp.arange(16, dtype=jnp.int32)
    pt = jax.random.randint(KEY, (n_blocks, 16), 0, 256)
    rk = ref.aes_key_expand(key_bytes)
    ct = aes_ctr(pt, rk, block_n=16, interpret=True)
    np.testing.assert_array_equal(ct, ref.aes_ctr_ref(pt, key_bytes))


def test_aes_ctr_roundtrip():
    """CTR decryption == encryption (xor keystream twice)."""
    key_bytes = jnp.flip(jnp.arange(16, dtype=jnp.int32))
    pt = jax.random.randint(KEY, (38, 16), 0, 256)
    ct = ref.aes_ctr_ref(pt, key_bytes)
    back = ref.aes_ctr_ref(ct, key_bytes)
    np.testing.assert_array_equal(back, pt)
    assert not np.array_equal(np.asarray(ct), np.asarray(pt))
