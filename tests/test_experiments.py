"""Scenario/experiment subsystem tests: fixed-seed determinism of the
arrival generators, artifact JSON schema, mode executors, and the paper's
throughput-claim regression gate at smoke duration."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import (BurstyArrivals, DiurnalArrivals, FaasdRuntime,
                        FunctionSpec, LoadSpec, PoissonArrivals, Simulator,
                        TraceReplay, drive, heavy_tailed_work,
                        knee_of_curve)
from repro.experiments import (SMOKE_DURATION_SCALE, ExperimentRunner,
                               build_artifact, build_scenarios,
                               get_scenario, get_suite, latency_histogram,
                               metric_row, validate_artifact,
                               write_artifact)


# ---------------------------------------------------------------------------
# Arrival generators: fixed-seed determinism + shape of the stream.


def test_poisson_arrivals_deterministic_and_rate_correct():
    p = PoissonArrivals(2000.0)
    a = p.times(np.random.default_rng(42), 2.0)
    b = p.times(np.random.default_rng(42), 2.0)
    np.testing.assert_array_equal(a, b)
    c = p.times(np.random.default_rng(43), 2.0)
    assert len(c) != len(a) or not np.array_equal(a, c)
    assert 0.85 * 4000 <= len(a) <= 1.15 * 4000
    assert np.all(np.diff(a) >= 0) and a[-1] < 2.0


def test_bursty_arrivals_deterministic_and_burstier_than_poisson():
    bp = BurstyArrivals(base_rps=200.0, burst_rps=8000.0)
    a = bp.times(np.random.default_rng(7), 2.0)
    b = bp.times(np.random.default_rng(7), 2.0)
    np.testing.assert_array_equal(a, b)
    # index of dispersion of interarrivals: MMPP >> Poisson (CV^2 = 1)
    gaps = np.diff(a)
    cv2_bursty = np.var(gaps) / np.mean(gaps) ** 2
    pois = PoissonArrivals(bp.mean_rps()).times(np.random.default_rng(7), 2.0)
    gaps_p = np.diff(pois)
    cv2_pois = np.var(gaps_p) / np.mean(gaps_p) ** 2
    assert cv2_bursty > 3.0 * cv2_pois


def test_diurnal_arrivals_follow_the_sinusoid():
    d = DiurnalArrivals(1000.0, amplitude=0.9, period_s=2.0)
    ts = d.times(np.random.default_rng(0), 2.0)
    # phase starts at the trough (t=0) and peaks mid-period (t=1): the
    # middle half of the window must carry most of the arrivals
    mid = int(np.sum((ts >= 0.5) & (ts < 1.5)))
    outer = len(ts) - mid
    assert mid > 2.0 * outer
    assert 0.8 * 2000 <= len(ts) <= 1.2 * 2000


def test_trace_replay_is_exact_and_clipped():
    tr = TraceReplay((0.5, 0.1, 0.9, 1.4), time_scale=1.0)
    np.testing.assert_allclose(tr.times(np.random.default_rng(0), 1.0),
                               [0.1, 0.5, 0.9])
    half = TraceReplay((0.5, 0.1, 0.9, 1.4), time_scale=0.5)
    np.testing.assert_allclose(half.times(np.random.default_rng(0), 1.0),
                               [0.05, 0.25, 0.45, 0.7])


def test_heavy_tailed_work_median_and_determinism():
    s1 = heavy_tailed_work(np.random.default_rng(3), 100.0, alpha=1.5)
    xs = np.array([s1() for _ in range(4000)])
    s2 = heavy_tailed_work(np.random.default_rng(3), 100.0, alpha=1.5)
    ys = np.array([s2() for _ in range(4000)])
    np.testing.assert_array_equal(xs, ys)
    assert 90.0 <= np.median(xs) <= 110.0
    assert xs.max() > 5 * np.median(xs)          # it is actually heavy-tailed
    assert xs.max() <= 100.0 * 200.0             # cap holds


# ---------------------------------------------------------------------------
# Mixed open-loop driver.


def test_mixed_open_loop_deterministic_and_per_fn():
    def once():
        sim = Simulator(seed=11)
        rt = FaasdRuntime(sim, backend="junctiond")
        rt.deploy_blocking(FunctionSpec(name="a"))
        rt.deploy_blocking(FunctionSpec(name="b"))
        return drive(rt, LoadSpec(PoissonArrivals(1200.0), ("a", "b"),
                                  weights=(0.8, 0.2), duration_s=0.4))

    r1, r2 = once(), once()
    assert r1["median_ms"] == r2["median_ms"]
    assert r1["n"] == r2["n"] > 100
    assert set(r1["per_fn"]) == {"a", "b"}
    assert r1["per_fn"]["a"].n > r1["per_fn"]["b"].n
    assert r1["rejected"] == 0


def test_knee_of_curve_respects_slo_and_achieved():
    curve = [
        {"nominal_rps": 100.0, "offered_rps": 100, "achieved_rps": 99,
         "p99_ms": 2.0, "rejected": 0},
        {"nominal_rps": 200.0, "offered_rps": 200, "achieved_rps": 198,
         "p99_ms": 9.0, "rejected": 0},
        {"nominal_rps": 400.0, "offered_rps": 400, "achieved_rps": 120,
         "p99_ms": 5.0, "rejected": 0},        # fails achieved fraction
        {"nominal_rps": 800.0, "offered_rps": 800, "achieved_rps": 799,
         "p99_ms": 50.0, "rejected": 0},       # fails SLO
    ]
    assert knee_of_curve(curve, slo_p99_ms=10.0) == 200.0


# ---------------------------------------------------------------------------
# Artifact schema.


def test_artifact_schema_roundtrip(tmp_path):
    sc = dataclasses.replace(get_scenario("paper-fig5"), seeds=(0,),
                             n_requests=30)
    doc = ExperimentRunner(smoke=True).run_suite([sc], suite="unit")
    validate_artifact(doc)
    path = tmp_path / "BENCH_unit.json"
    write_artifact(str(path), doc)
    loaded = json.loads(path.read_text())
    validate_artifact(loaded)
    assert loaded["suite"] == "unit"
    entry = loaded["scenarios"][0]
    assert entry["name"] == "paper-fig5"
    assert set(entry["backends"]) == {"containerd", "junctiond"}
    for res in entry["backends"].values():
        assert res["hist"]["counts"] and len(res["hist"]["edges_ms"]) == \
            len(res["hist"]["counts"]) + 1
    assert any(m["name"] == "fig5_median_reduction"
               for m in loaded["metrics"])
    assert loaded["failures"] == []


def test_validate_artifact_rejects_malformed():
    with pytest.raises(ValueError, match="missing top-level key"):
        validate_artifact({"schema_version": 1})
    doc = build_artifact("x", [{"name": "s"}], [metric_row("m", 1.0, "d")], [])
    with pytest.raises(ValueError, match="missing 'mode'"):
        validate_artifact(doc)
    doc = build_artifact("x", [], [{"name": "m"}], [])
    with pytest.raises(ValueError, match="metrics"):
        validate_artifact(doc)


def test_latency_histogram_handles_empty_and_counts():
    assert latency_histogram([]) == {"edges_ms": [], "counts": []}
    h = latency_histogram([0.1, 1.0, 10.0, 100.0], n_bins=8)
    assert sum(h["counts"]) == 4


# ---------------------------------------------------------------------------
# Runner modes + failure isolation.


def test_storm_mode_reports_deploy_and_invoke():
    sc = dataclasses.replace(get_scenario("cold-start-storm"), seeds=(0,),
                             storm_functions=4)
    entry = ExperimentRunner().run_scenario(sc)
    j = entry["backends"]["junctiond"]
    c = entry["backends"]["containerd"]
    assert j["n"] == c["n"] == 4
    assert j["single_deploy_ms"] == pytest.approx(3.4, rel=0.01)
    assert c["single_deploy_ms"] > 50 * j["single_deploy_ms"]
    assert entry["claims"]["storm_speedup"]["measured"] > 10


def test_runner_isolates_scenario_failures():
    bad = dataclasses.replace(
        get_scenario("paper-fig5"), name="bad",
        mode="bogus", seeds=(0,))       # unknown mode -> executor raises
    ok = dataclasses.replace(get_scenario("paper-fig5"), seeds=(0,),
                             n_requests=20)
    doc = ExperimentRunner(smoke=True).run_suite([bad, ok], suite="unit")
    assert {f["scenario"] for f in doc["failures"]} == {"bad"}
    assert doc["scenarios"][1]["backends"]      # the good one still ran
    validate_artifact(doc)


def test_suite_registry_covers_required_scenarios():
    reg = build_scenarios()
    names = {s.name for s in get_suite("scenarios")}
    for required in ("paper-fig5", "paper-fig6", "cold-start-storm",
                     "multi-tenant-mix", "bursty-burst", "model-endpoint"):
        assert required in names and required in reg
    assert len(names) >= 6
    for sc in get_suite("smoke"):
        assert set(sc.backends) == {"containerd", "junctiond"}


# ---------------------------------------------------------------------------
# Regression gate: the paper's headline throughput claim at smoke duration.


def test_fig6_throughput_ratio_regression_smoke():
    sc = get_scenario("paper-fig6")
    doc = ExperimentRunner(duration_scale=SMOKE_DURATION_SCALE,
                           smoke=True).run_suite([sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    ratio = next(m["value"] for m in doc["metrics"]
                 if m["name"] == "fig6_throughput_ratio")
    assert ratio >= 5.0, f"fig6 throughput ratio regressed: {ratio}x < 5x"
