"""Unit + property tests for the discrete-event engine."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.simulator import Simulator


def test_timeout_ordering():
    sim = Simulator()
    seen = []
    for d in (0.5, 0.1, 0.3):
        def make(d=d):
            def p():
                yield sim.timeout(d)
                seen.append(d)
            return p
        sim.process(make()())
    sim.run()
    assert seen == [0.1, 0.3, 0.5]
    assert sim.now == pytest.approx(0.5)


def test_event_value_passing():
    sim = Simulator()
    ev = sim.event()
    got = []

    def waiter():
        v = yield ev
        got.append(v)

    sim.process(waiter())

    def trigger():
        yield sim.timeout(1.0)
        ev.succeed("payload")

    sim.process(trigger())
    sim.run()
    assert got == ["payload"]


def test_queue_fifo_and_blocking():
    sim = Simulator()
    order = []

    def consumer(name):
        while True:
            item = yield sim.queue_ref.get()
            order.append((name, item))

    sim.queue_ref = sim.queue()

    def producer():
        for i in range(4):
            yield sim.timeout(0.1)
            sim.queue_ref.put(i)

    sim.process(consumer("c"))
    sim.process(producer())
    sim.run(until=10.0)
    assert [i for _, i in order] == [0, 1, 2, 3]


def test_process_completion_event():
    sim = Simulator()

    def inner():
        yield sim.timeout(2.0)
        return 42

    def outer(results):
        p = sim.process(inner())
        v = yield p.completion
        results.append(v)

    results = []
    sim.process(outer(results))
    sim.run()
    assert results == [42]


@settings(max_examples=50, deadline=None)
@given(delays=st.lists(st.floats(min_value=0.0, max_value=100.0,
                                 allow_nan=False), min_size=1, max_size=40))
def test_property_event_time_monotonic(delays):
    """PROPERTY: simulation time never goes backwards and every scheduled
    callback fires exactly once."""
    sim = Simulator()
    fired = []

    def make(d):
        def p():
            yield sim.timeout(d)
            fired.append((d, sim.now))
        return p

    for d in delays:
        sim.process(make(d)())
    sim.run()
    assert len(fired) == len(delays)
    times = [t for _, t in sorted(fired)]
    assert all(t2 >= t1 for t1, t2 in zip(times, times[1:]))
    assert sim.now == pytest.approx(max(delays))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_property_determinism(seed):
    """PROPERTY: identical seeds produce bit-identical latency traces."""
    from repro.core import FaasdRuntime, FunctionSpec, run_sequential

    def run():
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend="junctiond")
        rt.deploy_blocking(FunctionSpec(name="aes"))
        return run_sequential(rt, "aes", n=10)

    a, b = run(), run()
    assert a.median_ms == b.median_ms
    assert a.p99_ms == b.p99_ms
