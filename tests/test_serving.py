"""Serving substrate tests: paged KV manager invariants (hypothesis),
continuous batcher lifecycle, engine generation."""
import dataclasses

import pytest
from _hypothesis_compat import given, settings, st

from repro.config import get_arch, reduced
from repro.serving import ContinuousBatcher, PagedKVManager, ServingEngine


def _cfg():
    return dataclasses.replace(reduced(get_arch("qwen3-1.7b")), dtype="float32")


# ---------------------------------------------------------------------------
def test_kv_admit_release_cycle():
    kv = PagedKVManager(_cfg(), n_slots=2, max_seq_len=64)
    a = kv.admit()
    b = kv.admit()
    assert not kv.can_admit()
    with pytest.raises(RuntimeError):
        kv.admit()
    kv.release(a.seq_id)
    c = kv.admit()
    assert c.slot == a.slot          # slot reuse
    kv.release(b.seq_id)
    kv.release(c.seq_id)
    assert kv.used_pages == 0


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["admit", "release", "advance"]),
                              st.integers(0, 7)), max_size=60))
def test_property_kv_slots_never_leak(ops):
    """PROPERTY: free slots + live seqs == n_slots; pages non-negative and
    bounded; release/advance of unknown ids rejected."""
    kv = PagedKVManager(_cfg(), n_slots=4, max_seq_len=128)
    live = {}
    for op, arg in ops:
        if op == "admit" and kv.can_admit():
            st_ = kv.admit()
            live[st_.seq_id] = st_
        elif op == "release" and live:
            sid = sorted(live)[arg % len(live)]
            kv.release(sid)
            del live[sid]
        elif op == "advance" and live:
            sid = sorted(live)[arg % len(live)]
            if live[sid].length < 120:
                kv.advance(sid, 8)
        assert len(kv.free_slots) + len(kv.seqs) == 4
        assert 0 <= kv.used_pages <= kv.total_pages
    assert set(kv.seqs) == set(live)


def test_batcher_lifecycle():
    kv = PagedKVManager(_cfg(), n_slots=2, max_seq_len=64)
    b = ContinuousBatcher(kv, max_batch=2)
    r1 = b.submit([1, 2, 3], max_new_tokens=2)
    r2 = b.submit([4, 5, 6], max_new_tokens=1)
    r3 = b.submit([7, 8, 9], max_new_tokens=1)
    admitted = b.admit_ready()
    assert len(admitted) == 2 and len(b.waiting) == 1
    slots = b.active_slots
    b.record_token(slots[1], 11)     # r2 done after 1 token
    assert r2.done and r2.generated == [11]
    assert len(b.admit_ready()) == 1  # r3 takes the freed slot
    b.record_token(slots[0], 21)
    b.record_token(slots[0], 22)
    assert r1.done and r1.generated == [21, 22]
    for s in list(b.running):
        b.record_token(s, 31)
    assert r3.done
    assert not b.has_work()


def test_engine_generates_deterministic_greedy():
    eng1 = ServingEngine(_cfg(), batch_slots=2, max_seq_len=32, seed=3)
    eng2 = ServingEngine(_cfg(), batch_slots=2, max_seq_len=32, seed=3)
    p = [[1, 2, 3, 4], [9, 8, 7, 6]]
    assert eng1.generate(p, max_new_tokens=5) == eng2.generate(p, max_new_tokens=5)
