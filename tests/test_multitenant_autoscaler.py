"""Multi-tenant hosting + autoscaler behaviour (paper §1/§2.1/§3)."""
from repro.core import FaasdRuntime, FunctionSpec, Simulator
from repro.core.autoscaler import Autoscaler, QueueDepthPolicy
from repro.core.multitenant import run_zipf_workload
from repro.core.scheduler import PollingModel


def test_centralized_hosts_more_functions_than_per_instance():
    cen = run_zipf_workload("junctiond", n_functions=64, total_rps=600,
                            duration_s=0.4)
    per = run_zipf_workload("junctiond", n_functions=64, total_rps=600,
                            duration_s=0.4, polling=PollingModel.PER_INSTANCE)
    assert cen.hosted == 64
    assert per.hosted < 64                      # polling cores exhausted
    assert cen.cores_for_work > per.cores_for_work


def test_cold_tier_latency_not_penalised():
    """Rarely-invoked functions must not pay a polling/wakeup tax under the
    centralized scheduler (the paper's density argument)."""
    r = run_zipf_workload("junctiond", n_functions=32, total_rps=1000,
                          duration_s=0.6)
    assert r.cold_tier.n > 0
    assert r.cold_tier.median_ms < r.overall.median_ms * 1.5


def test_autoscaler_scales_up_and_down():
    sim = Simulator(seed=0)
    rt = FaasdRuntime(sim, backend="junctiond")
    rt.deploy_blocking(FunctionSpec(name="f", work_us=2000.0, max_cores=8))
    asc = Autoscaler(sim, rt, QueueDepthPolicy(period_s=0.05,
                                               target_inflight_per_replica=2.0))
    asc.run()

    def burst():
        for _ in range(600):
            yield sim.timeout(0.0001)           # 10k rps burst of 2ms calls

            def one():
                asc.on_arrival("f")
                yield from rt.invoke("f")
                asc.on_done("f")

            sim.process(one())

    sim.process(burst())
    sim.run(until=1.0)
    ups = [e for e in asc.scale_events if e.up]
    downs = [e for e in asc.scale_events if not e.up]
    assert ups, "autoscaler never scaled up under a 2000rps burst"
    assert downs, "autoscaler never scaled back down after the burst"
    # replica truth is the backend's record, not a shadow dict
    assert asc.replicas("f") == rt.backend.lookup("f").replicas >= 1
    for e in ups:
        if e.ready:
            assert e.t_ready >= e.t_decision >= e.t_request


def test_autoscaler_respects_bounds():
    sim = Simulator(seed=0)
    rt = FaasdRuntime(sim, backend="junctiond")
    rt.deploy_blocking(FunctionSpec(name="f"))
    pol = QueueDepthPolicy(min_replicas=1, max_replicas=4, period_s=0.02)
    asc = Autoscaler(sim, rt, pol)
    asc.run()
    asc.inflight["f"] = 10_000                  # absurd load
    sim.run(until=1.0)
    assert asc.replicas("f") == rt.backend.lookup("f").replicas <= 4
