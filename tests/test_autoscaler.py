"""Backend-aware control plane: ScalePolicy implementations (hysteresis,
clamping, lead-time derivation from the ColdStartModel), the Autoscaler's
backend-sourced replica truth and structured scale-event telemetry, the
workload-driver hooks, the per-backend reaction-time ordering (the
control-plane analogue of the fig5/coldstart orderings), schema-v3
artifacts, and the runner's autoscaled scenarios."""
import pytest

from repro.core import (Autoscaler, FaasdRuntime, FunctionSpec,
                        LeadTimePolicy, LoadSpec, PoissonArrivals,
                        QueueDepthPolicy, ScalePolicy, Simulator,
                        available_backends, drive, get_backend_class,
                        run_sequential)
from repro.experiments import (AutoscalerSpec, ExperimentRunner,
                               build_artifact, get_scenario, get_suite,
                               metric_row, validate_artifact)
from repro.experiments.artifacts import SCHEMA_VERSION

ALL_BACKENDS = available_backends()
FOUR = ("containerd", "junctiond", "quark", "wasm")


def _runtime(backend, seed=0, **kw):
    sim = Simulator(seed=seed)
    return FaasdRuntime(sim, backend=backend, **kw)


def _autoscaled(backend, policy, fn="f", seed=0, **fn_kw):
    rt = _runtime(backend, seed=seed)
    rt.deploy_blocking(FunctionSpec(name=fn, **fn_kw))
    asc = Autoscaler(rt.sim, rt, policy)
    asc.run()
    return rt, asc


# ---------------------------------------------------------------------------
# Policies as pure functions.


def test_queue_depth_policy_hysteresis_band_holds_steady():
    pol = QueueDepthPolicy(target_inflight_per_replica=4.0,
                           scale_down_hysteresis=0.5)
    cs = get_backend_class("junctiond").coldstart
    # load inside [target*hyst*cur, target*cur] = [8, 16] for cur=4: no move
    for load in (8, 12, 16):
        assert pol.desired(inflight=load, replicas=4, arrival_rate_rps=0.0,
                           coldstart=cs) == 4
    assert pol.desired(inflight=17, replicas=4, arrival_rate_rps=0.0,
                       coldstart=cs) == 8
    assert pol.desired(inflight=7, replicas=4, arrival_rate_rps=0.0,
                       coldstart=cs) == 2


def test_policies_clamp_to_min_max():
    cs = get_backend_class("junctiond").coldstart
    for pol in (QueueDepthPolicy(min_replicas=2, max_replicas=4),
                LeadTimePolicy(min_replicas=2, max_replicas=4)):
        assert pol.desired(inflight=10_000, replicas=4,
                           arrival_rate_rps=50_000.0, coldstart=cs) == 4
        assert pol.desired(inflight=0, replicas=2, arrival_rate_rps=0.0,
                           coldstart=cs) == 2
        assert isinstance(pol, ScalePolicy)


def test_lead_time_period_and_headroom_derive_from_coldstart():
    pol = LeadTimePolicy(target_inflight_per_replica=2.0)
    periods = {b: pol.control_period(get_backend_class(b).coldstart)
               for b in FOUR + ("firecracker", "gvisor")}
    # sub-ms scale-up -> floor; 100s-of-ms scale-up -> ceiling.  The
    # snapshotting microVM's 5 ms restore also lands on the floor (its
    # ColdStartModel advertises the restore path as the scale cost),
    # while gvisor's 240 ms Sentry bring-up clamps at the ceiling.
    assert periods["junctiond"] == periods["wasm"] == pol.period_floor_s
    assert periods["firecracker"] == pytest.approx(max(
        pol.period_floor_s,
        pol.lead_mult * get_backend_class("firecracker").coldstart.scale_seconds))
    assert periods["firecracker"] < pol.period_ceil_s
    assert periods["containerd"] == periods["quark"] == \
        periods["gvisor"] == pol.period_ceil_s
    # headroom covers the arrivals landing during the scale-up lead time:
    # at 1000 rps a 270 ms containerd scale-up eats 270 arrivals (135
    # replicas at target 2 -> clamped), junctiond's 0.2 ms eats ~0
    slow = get_backend_class("containerd").coldstart
    fast = get_backend_class("junctiond").coldstart
    want_slow = pol.desired(inflight=5, replicas=1, arrival_rate_rps=1000.0,
                            coldstart=slow)
    want_fast = pol.desired(inflight=5, replicas=1, arrival_rate_rps=1000.0,
                            coldstart=fast)
    assert want_slow == pol.max_replicas
    assert want_fast == 4               # ceil(5/2) + ceil(0.2/2) = 3 + 1


# ---------------------------------------------------------------------------
# Autoscaler: backend truth, state drift, off-critical-path scaling.


def test_replica_truth_comes_from_backend_lookup():
    rt, asc = _autoscaled("junctiond", QueueDepthPolicy(period_s=0.02))
    assert asc.replicas("f") == rt.backend.lookup("f").replicas == 1
    asc.inflight["f"] = 100
    rt.sim.run(until=0.5)
    assert asc.replicas("f") == rt.backend.lookup("f").replicas > 1
    assert asc.replicas("ghost") is None


def test_external_remove_produces_no_ghost_scale_events():
    """Regression for the shadow-dict drift: scaling pressure on a
    function removed behind the controller's back must not emit scale
    events, and the stale load signal is dropped."""
    rt, asc = _autoscaled("junctiond", QueueDepthPolicy(period_s=0.02))
    for _ in range(50):
        asc.on_arrival("f")
    rt.backend.remove("f")              # external remove, controller unaware
    rt.sim.run(until=0.2)
    assert asc.scale_events == []
    assert "f" not in asc.inflight      # stale state dropped at the tick
    assert "f" not in asc._pressure_t0
    # redeploy re-enters the control loop with the backend's real count
    rt.deploy_blocking(FunctionSpec(name="f"))
    for _ in range(50):
        asc.on_arrival("f")
    rt.sim.run(until=0.4)
    assert any(e.up for e in asc.scale_events)
    assert asc.replicas("f") == rt.backend.lookup("f").replicas


def test_scaling_stays_off_the_critical_path():
    """Warm invocations must be byte-identical with and without the
    controller running: decisions spawn their own processes and consume
    neither sim time nor RNG draws on the invoke path."""
    def latencies(with_autoscaler):
        rt = _runtime("containerd", seed=3)
        rt.deploy_blocking(FunctionSpec(name="f"))
        if with_autoscaler:
            asc = Autoscaler(rt.sim, rt, QueueDepthPolicy(
                period_s=0.01, target_inflight_per_replica=0.5))
            asc.run()
            asc.inflight["f"] = 100      # constant pressure -> scale ops fly
        run_sequential(rt, "f", n=40)
        return rt.latencies_ms()

    assert latencies(False) == latencies(True)


def test_scale_events_carry_request_decision_ready_timeline():
    rt, asc = _autoscaled("containerd", LeadTimePolicy(
        target_inflight_per_replica=2.0))
    sim = rt.sim
    t0 = sim.now                        # deploy already consumed sim time
    for _ in range(10):                 # pressure onset now
        asc.on_arrival("f")
    sim.run(until=t0 + 2.0)
    ups = [e for e in asc.scale_events if e.up]
    assert ups and ups[0].ready
    e = ups[0]
    assert e.t_request <= e.t_decision < e.t_ready
    # decision waited for the 0.25 s control period; the backend then took
    # its 270 ms scale-up on top
    assert e.t_decision == pytest.approx(t0 + 0.25)
    assert e.t_request == pytest.approx(t0)
    assert e.t_ready - e.t_decision == pytest.approx(
        rt.backend.coldstart.scale_seconds)
    assert e.cold_starts == e.to_replicas - e.from_replicas > 0
    tel = asc.telemetry()
    assert tel["policy"] == "lead-time"
    assert tel["n_scale_events"] == len(asc.scale_events)
    assert tel["cold_starts"] >= e.cold_starts
    assert tel["timeline"][0][2] == e.to_replicas


@pytest.mark.parametrize("name", ALL_BACKENDS)
def test_scale_up_reaction_time_tracks_coldstart_class(name):
    """Conformance-style: every backend's measured reaction time equals
    its modeled scale-up cost (pressure observed at the tick, capacity
    ready one scale op later)."""
    rt, asc = _autoscaled(name, LeadTimePolicy(
        target_inflight_per_replica=2.0), max_cores=8)
    asc.inflight["f"] = 3               # need one extra replica, no headroom
    rt.sim.run(until=2.0)
    ups = [e for e in asc.scale_events if e.up and e.ready]
    assert len(ups) == 1
    assert ups[0].reaction_s == pytest.approx(
        rt.backend.coldstart.scale_seconds * ups[0].cold_starts)


def test_reaction_time_ordering_across_backends():
    """The control-plane ordering the cold-start asymmetry buys, across
    the full isolation spectrum: junctiond reacts fastest, wasm close
    behind, the microVM's snapshot restore single-digit-ms, gvisor just
    under containerd (Sentry bring-up, no guest Linux), containerd two
    orders slower than junctiond, quark slowest (guest-kernel boot on
    top)."""
    def reaction_s(name):
        rt, asc = _autoscaled(name, LeadTimePolicy(
            target_inflight_per_replica=2.0), max_cores=8)
        asc.inflight["f"] = 3
        rt.sim.run(until=2.0)
        ups = [e for e in asc.scale_events if e.up and e.ready]
        return ups[0].reaction_s

    r = {b: reaction_s(b) for b in FOUR + ("firecracker", "gvisor")}
    assert (r["junctiond"] < r["wasm"] < r["firecracker"]
            < r["gvisor"] < r["containerd"] <= r["quark"])
    assert r["containerd"] / r["junctiond"] > 100
    # the snapshot restore keeps the microVM's reaction junctiond-class
    # (single-digit ms), not container-class (hundreds of ms)
    assert r["firecracker"] < 30 * r["junctiond"]
    assert r["containerd"] > 10 * r["firecracker"]


def test_reaction_time_not_inflated_by_stale_pressure():
    """Regression: pressure that subsides without a scale-up (e.g. the
    controller clamped at max_replicas) must not leave its onset behind —
    a scale-up during a much later burst would otherwise inherit it and
    report a wildly inflated reaction time."""
    rt, asc = _autoscaled("junctiond", LeadTimePolicy(
        target_inflight_per_replica=2.0, max_replicas=2), max_cores=8)
    sim = rt.sim
    t0 = sim.now
    asc.inflight["f"] = 100             # burst 1: pins at max_replicas
    sim.run(until=t0 + 0.2)
    asc.inflight["f"] = 0               # burst drains; quiet for a second
    sim.run(until=t0 + 1.2)
    assert "f" not in asc._pressure_t0  # onset cleared while quiet
    n_before = len(asc.scale_events)
    asc.inflight["f"] = 100             # burst 2, over a second later
    sim.run(until=t0 + 1.5)
    ups = [e for e in asc.scale_events[n_before:] if e.up and e.ready]
    assert ups
    # reaction reflects burst 2 only (a control period + the scale op),
    # not the 1.2 s since burst 1
    assert ups[0].reaction_s < 0.1


def test_cold_path_arrivals_counted_while_scaleup_in_flight():
    rt, asc = _autoscaled("containerd", LeadTimePolicy(
        target_inflight_per_replica=2.0))
    sim = rt.sim

    def load():
        for _ in range(40):             # arrivals spanning the 270ms scale-up
            asc.on_arrival("f")
            yield sim.timeout(0.02)

    sim.process(load())
    sim.run(until=2.0)
    assert any(e.up for e in asc.scale_events)
    assert asc.cold_path_arrivals > 0
    assert asc.cold_path_arrivals == asc.telemetry()["cold_path_arrivals"]


# ---------------------------------------------------------------------------
# Workload-driver hooks.


class _TapObserver:
    """Minimal SimObserver recording every hook dispatch."""

    def __init__(self):
        self.events = []

    def on_arrival(self, fn_name):
        self.events.append(("arr", fn_name))

    def on_done(self, fn_name):
        self.events.append(("done", fn_name))


def test_open_loop_drivers_feed_hooks_balanced():
    rt = _runtime("junctiond", seed=5)
    rt.deploy_blocking(FunctionSpec(name="f"))
    obs = _TapObserver()
    drive(rt, LoadSpec.single("f", 500.0, duration_s=0.3, warmup_s=0.1),
          observer=obs)
    arrs = [e for e in obs.events if e[0] == "arr"]
    dones = [e for e in obs.events if e[0] == "done"]
    assert len(arrs) > 50 and len(arrs) == len(dones)
    assert {fn for _, fn in obs.events} == {"f"}


def test_mixed_open_loop_hooks_see_the_picked_function():
    rt = _runtime("junctiond", seed=6)
    rt.deploy_blocking(FunctionSpec(name="a"))
    rt.deploy_blocking(FunctionSpec(name="b"))
    obs = _TapObserver()
    res = drive(rt, LoadSpec(PoissonArrivals(800.0), ("a", "b"),
                             weights=(0.7, 0.3), duration_s=0.3),
                observer=obs)
    counts = {}
    for kind, fn in obs.events:
        if kind == "arr":
            counts[fn] = counts.get(fn, 0) + 1
    assert set(counts) == {"a", "b"}
    assert counts["a"] > counts["b"]
    assert sum(counts.values()) >= res["n"]     # hooks fire pre-warmup too


# ---------------------------------------------------------------------------
# AutoscalerSpec + schema v3.


def test_autoscaler_spec_builds_policies():
    spec = AutoscalerSpec(policy="queue-depth", period_s=0.1,
                          max_replicas=8)
    pol = spec.build()
    assert isinstance(pol, QueueDepthPolicy)
    assert pol.period_s == 0.1 and pol.max_replicas == 8
    lead = AutoscalerSpec(policy="lead-time", lead_mult=3.0).build()
    assert isinstance(lead, LeadTimePolicy) and lead.lead_mult == 3.0
    with pytest.raises(ValueError, match="unknown autoscaler policy"):
        AutoscalerSpec(policy="bogus").build()


def test_schema_v3_validates_autoscaler_blocks():
    assert SCHEMA_VERSION == 6
    good_block = {"policy": "lead-time", "n_scale_events": 3,
                  "cold_starts": 2, "cold_path_arrivals": 5,
                  "reaction_p50_ms": 1.5}
    doc = build_artifact("unit", [{
        "name": "s", "mode": "open", "description": "d",
        "backend_set": ["junctiond"],
        "backends": {"junctiond": {"autoscaler": good_block}}}],
        [metric_row("m", 1.0, "d")], [])
    validate_artifact(doc)
    bad = build_artifact("unit", [{
        "name": "s", "mode": "open", "description": "d",
        "backend_set": ["junctiond"],
        "backends": {"junctiond": {"autoscaler": {"policy": "lead-time"}}}}],
        [], [])
    with pytest.raises(ValueError, match="autoscaler missing"):
        validate_artifact(bad)
    # v3 documents require the block's keys too; v2 documents never did
    bad["schema_version"] = 3
    with pytest.raises(ValueError, match="autoscaler missing"):
        validate_artifact(bad)
    bad["schema_version"] = 2
    validate_artifact(bad)


# ---------------------------------------------------------------------------
# Runner integration: the autoscaled scenarios.


def test_autoscale_burst_claims_favor_junctiond():
    sc = get_scenario("autoscale-burst")
    doc = ExperimentRunner(duration_scale=0.33, smoke=True).run_suite(
        [sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    validate_artifact(doc)
    entry = doc["scenarios"][0]
    assert entry["autoscaler_spec"]["policy"] == "lead-time"
    for backend, res in entry["backends"].items():
        block = res["autoscaler"]
        assert block["n_scale_events"] > 0, f"{backend} never scaled"
        assert block["reactions_ms"]
        assert block["timeline"]
        assert any(r.get("scale_events") for r in res["curve"])
    claims = entry["claims"]
    assert claims["scaleup_reaction_ratio"]["measured"] > 1.0
    names = {m["name"]: m["value"] for m in doc["metrics"]}
    assert names["autoscale_reaction_ratio"] > 1.0
    assert "scn_autoscale-burst_junctiond_scaleup_reaction" in names


def test_mixed_cold_warm_measures_interference_with_telemetry():
    sc = get_scenario("mixed-cold-warm")
    doc = ExperimentRunner(duration_scale=0.33, smoke=True).run_suite(
        [sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    validate_artifact(doc)
    entry = doc["scenarios"][0]
    for backend, res in entry["backends"].items():
        assert res["mode"] == "mixed"
        assert res["warm_p99_before_ms"] > 0
        assert res["warm_p99_during_ms"] > 0
        assert res["storm_deploy_median_ms"] > 0
        assert res["autoscaler"]["n_scale_events"] > 0
    claims = entry["claims"]
    assert claims["baseline_warm_p99_inflation"]["measured"] > 0
    # the storm itself resolves orders of magnitude faster on junctiond
    assert (claims["baseline_storm_total_ms"]["measured"]
            > 10 * claims["treatment_storm_total_ms"]["measured"])


def test_autoscale_suite_and_smoke_cover_the_new_scenarios():
    smoke = {s.name for s in get_suite("smoke")}
    assert {"autoscale-burst", "autoscale-diurnal",
            "mixed-cold-warm"} <= smoke
    trio = get_suite("autoscale")
    assert all(s.autoscaler is not None for s in trio)
    assert {s.mode for s in trio} == {"open", "mixed"}
