"""Property tests for CorePool under mixed fast/generator/lazy traffic.

The event-engine fast path (``acquire_fast``/``release_fast``), the
legacy generator path (``consume``), and the fused driver's lazy
releases (``release_at``) all share one core pool and one waiter queue.
These properties pin the pool's invariants under arbitrary interleaved
schedules: ``busy`` stays within ``[0, n_cores]``, the queued-weight
bookkeeping drains to zero, reservation-across-gap never strands a
core, and the fused fast path's deferred accounting matches the
per-station machine on a contention-free schedule.
"""
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import FaasdRuntime, FunctionSpec, LoadSpec, Simulator, drive
from repro.core.backends import get_backend_class
from repro.core.resources import CorePool

import repro.core.workload as workload


def _pool(n_cores: int):
    sim = Simulator(seed=0)
    costs = get_backend_class("containerd").runtime
    return sim, CorePool(sim, n_cores, costs)


# job: (kind, arrival_s, cpu_s, gap_s)
_JOB = st.tuples(st.sampled_from(["fast", "gen", "lazy"]),
                 st.floats(min_value=0.0, max_value=2.0),
                 st.floats(min_value=1e-6, max_value=0.3),
                 st.floats(min_value=0.0, max_value=0.1))


@given(st.lists(_JOB, min_size=1, max_size=40),
       st.integers(min_value=2, max_value=8))
@settings(max_examples=50, deadline=None)
def test_mixed_traffic_invariants(jobs, n_cores):
    sim, pool = _pool(n_cores)
    state = {"done": 0, "lazy": 0}
    expect_done = 0

    def check():
        assert 0 <= pool.busy <= pool.n_cores, (pool.busy, pool.n_cores)
        assert pool._queued_weight >= 0

    def fast(cpu, gap):
        def granted(start):
            check()
            eff = cpu * pool.thrash()
            sim._schedule(start + eff - sim.now, done, eff)

        def done(eff):
            pool.release_fast(eff)
            state["done"] += 1
            check()

        pool.acquire_fast(sim.now + gap, granted)

    def gen(cpu):
        def job():
            yield from pool.consume(cpu)
            state["done"] += 1
            check()
        sim.process(job())

    def lazy(cpu):
        # a fused off-path hold: only taken when the pool is
        # uncontended, released lazily with no scheduled event
        if not pool._waiters and pool.busy < pool.n_cores:
            pool.busy += 1
            pool.release_at(sim.now + cpu)
            state["lazy"] += 1

    for kind, arrival, cpu, gap in jobs:
        if kind == "fast":
            expect_done += 1
            sim._schedule(arrival, fast, cpu, gap)
        elif kind == "gen":
            expect_done += 1
            sim._schedule(arrival, gen, cpu)
        else:
            sim._schedule(arrival, lazy, cpu)

    sim.run()
    # every queued grant drained, nothing stranded
    assert state["done"] == expect_done
    assert len(pool._waiters) == 0
    assert pool._queued_weight == 0
    assert pool.served == expect_done
    # lazy holds release on the next drain; force one past all times
    pool._drain(float("inf"))
    assert pool.busy == 0
    assert not pool._off_pend


@given(st.lists(st.tuples(st.floats(min_value=0.0, max_value=0.5),
                          st.floats(min_value=1e-6, max_value=0.2),
                          st.floats(min_value=0.0, max_value=0.05)),
                min_size=1, max_size=30),
       st.integers(min_value=2, max_value=6))
@settings(max_examples=50, deadline=None)
def test_reservation_across_gap_never_strands_a_core(jobs, n_cores):
    """Holds that reserve a core through a future ``avail_t`` (the
    in-flight network gap) must all complete and return the pool to
    empty, whatever the interleaving."""
    sim, pool = _pool(n_cores)
    done = []

    def hold(cpu):
        def granted(start):
            sim._schedule(start + cpu - sim.now, release)

        def release():
            pool.release_fast(cpu)
            done.append(sim.now)

        return granted

    for arrival, cpu, gap in jobs:
        sim._schedule(arrival,
                      lambda c=cpu, g=gap:
                      pool.acquire_fast(sim.now + g, hold(c)))

    sim.run()
    assert len(done) == len(jobs)
    assert pool.busy == 0
    assert len(pool._waiters) == 0
    assert pool._queued_weight == 0


def _drive_totals(fused: bool, rate: float = 150.0, n_cores: int = 64):
    old = workload.FUSED_FAST_PATH
    workload.FUSED_FAST_PATH = fused
    try:
        sim = Simulator(seed=11)
        rt = FaasdRuntime(sim, backend="containerd", n_cores=n_cores)
        rt.deploy_blocking(FunctionSpec(name="aes"))
        res = drive(rt, LoadSpec.single("aes", rate, duration_s=1.0),
                    engine="events")
    finally:
        workload.FUSED_FAST_PATH = old
    return res, rt.cores.busy_time, rt.cores.served


def test_fused_and_unfused_agree_when_uncontended():
    """On a contention-free schedule (64 cores, light load) the fused
    fast path is a pure event-count optimisation: per-request timelines,
    busy_time and served totals must match the per-station machine."""
    res_f, busy_f, served_f = _drive_totals(True)
    res_u, busy_u, served_u = _drive_totals(False)
    assert served_f == served_u
    assert busy_f == pytest.approx(busy_u, rel=1e-9)
    assert res_f["n"] == res_u["n"]
    assert res_f["latencies_ms"] == pytest.approx(res_u["latencies_ms"],
                                                 rel=1e-9)


def test_fused_toggle_does_not_change_fleet_telemetry_shape():
    from repro.fleet import Cluster
    sim = Simulator(seed=5)
    cl = Cluster(sim, 4, backend="containerd")
    cl.deploy_blocking(FunctionSpec(name="aes"))
    res = drive(cl, LoadSpec.single("aes", 1500.0, duration_s=1.0))
    rows = res["fleet"]["workers"]
    assert len(rows) == 4
    assert all(w["n"] > 0 for w in rows)
    total_hic = sum(w.runtime.stack.hiccups for w in cl.workers)
    spread = sum(1 for w in cl.workers if w.runtime.stack.hiccups > 0)
    if total_hic >= 4:
        # hiccups are apportioned across routed workers, not booked on
        # the reference worker alone
        assert spread > 1
