"""Training substrate: optimizer semantics, checkpoint round-trip, loss
decreases on structured synthetic data, data-pipeline determinism."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.config import get_arch, reduced
from repro.models import transformer as T
from repro.train import AdamWConfig, DataConfig, SyntheticLM, train
from repro.train.checkpoint import restore, save
from repro.train.optimizer import apply_updates, global_norm, init_state, lr_schedule


def _cfg():
    return dataclasses.replace(reduced(get_arch("h2o-danube-3-4b")), dtype="float32")


def test_grad_clipping_bounds_update():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 1e6)}
    state = init_state(params)
    cfg = AdamWConfig(grad_clip=1.0, lr=0.1, warmup_steps=0, weight_decay=0.0)
    _, _, m = apply_updates(cfg, params, grads, state)
    assert m["grad_norm"] > 1e6  # reported norm is pre-clip
    clipped = grads["w"] * jnp.minimum(1.0, 1.0 / m["grad_norm"])
    assert float(global_norm({"w": clipped})) <= 1.0 + 1e-5


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup ascending
    assert lrs[-1] == pytest.approx(1e-4, rel=0.1)  # decays to min ratio
    assert max(lrs) <= 1e-3 + 1e-9


def test_checkpoint_roundtrip():
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_state(params)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, params, opt, step=17)
        p2, o2, step = restore(path, params, opt)
        assert step == 17
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(opt),
                        jax.tree_util.tree_leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loss_decreases_on_synthetic_data():
    cfg = _cfg()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4, seed=1)
    res = train(cfg, SyntheticLM(dc).batches(), steps=25,
                opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=25),
                log_every=24)
    hist = res["history"]
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8


def test_data_pipeline_determinism_and_sharding():
    dc = DataConfig(vocab_size=1000, seq_len=64, batch_size=2, seed=7)
    a = next(SyntheticLM(dc, shard=0).batches())
    b = next(SyntheticLM(dc, shard=0).batches())
    c = next(SyntheticLM(dc, shard=1).batches())
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    # next-token alignment invariant
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), zipf=st.floats(1.01, 2.0))
def test_property_synthetic_tokens_in_vocab(seed, zipf):
    """PROPERTY: every generated token is a valid vocab id."""
    dc = DataConfig(vocab_size=257, seq_len=48, batch_size=2, seed=seed,
                    zipf_a=zipf)
    batch = next(SyntheticLM(dc).batches())
    for k in ("tokens", "labels"):
        assert batch[k].min() >= 0
        assert batch[k].max() < 257
