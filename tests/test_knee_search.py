"""Adaptive SLO-knee search: engine convergence/determinism/budget, the
runner's search mode (schema v4 artifacts, knee-row-by-index tracking),
and regressions for the open-loop accounting fixes that ride along
(per-run rejected delta, warm-inflation NaN guard)."""
import dataclasses
import math

import pytest

from repro.core import (FaasdRuntime, FunctionSpec, KneeSearch, LoadSpec,
                        PoissonArrivals, Simulator, drive,
                        knee_index_of_curve, knee_of_curve)
from repro.experiments import (ExperimentRunner, Scenario, SearchSpec,
                               build_artifact, get_scenario, metric_row,
                               validate_artifact)
from repro.experiments.scenario import FunctionProfile


# ---------------------------------------------------------------------------
# Engine unit behaviour on a synthetic (sim-free) probe: an analytic
# latency curve with a sharp knee, optionally with the throughput
# collapse this runtime exhibits under deep overload.


def _synthetic_probe(true_knee: float, log=None):
    def probe(rate, phase):
        over = rate > true_knee
        # throughput collapses under deep overload (measured behaviour:
        # offered 4x the knee completes at well under the knee rate)
        comp = min(rate, true_knee) if rate <= 2 * true_knee \
            else 0.3 * true_knee
        row = {
            "p99_ms": 2.0 if not over else 40.0 * rate / true_knee,
            "achieved_rps": min(rate, true_knee * 1.1),
            "completion_rps": comp,
            "completed_frac": 1.0 if not over else 0.4,
            "rejected": 0,
            "median_ms": 1.0,
        }
        if log is not None:
            log.append((round(rate, 6), phase))
        return row
    return probe


@pytest.mark.parametrize("true_knee", [230.0, 1250.0, 12700.0])
@pytest.mark.parametrize("rate0", [500.0, 4000.0])
def test_knee_search_converges_on_synthetic_curve(true_knee, rate0):
    res = KneeSearch(_synthetic_probe(true_knee), slo_p99_ms=10.0,
                     rate0=rate0, rel_tol=0.10, max_probes=14).run()
    assert res.converged
    assert res.knee_rps == pytest.approx(true_knee, rel=0.10)
    assert res.knee_rps <= true_knee          # lo is a certified pass
    assert res.lo_rps <= res.hi_rps
    assert res.n_probes == len(res.trace) <= 14


def test_knee_search_is_deterministic():
    a_log, b_log = [], []
    a = KneeSearch(_synthetic_probe(900.0, a_log), 10.0, rate0=500.0).run()
    b = KneeSearch(_synthetic_probe(900.0, b_log), 10.0, rate0=500.0).run()
    assert a_log == b_log
    assert a.knee_rps == b.knee_rps and a.n_probes == b.n_probes


def test_knee_search_respects_probe_budget():
    log = []
    res = KneeSearch(_synthetic_probe(1250.0, log), 10.0, rate0=100.0,
                     rel_tol=0.01, max_probes=4).run()
    assert len(log) == res.n_probes <= 4
    # budget too small for 1% tolerance from a 12x-off start
    assert not res.converged


def test_knee_search_reports_zero_when_nothing_sustainable():
    def always_fail(rate, phase):
        return {"p99_ms": 500.0, "achieved_rps": rate * 0.2,
                "completion_rps": rate * 0.2, "completed_frac": 0.2,
                "rejected": 0}
    res = KneeSearch(always_fail, 10.0, rate0=1000.0, max_probes=10).run()
    assert res.knee_rps == 0.0
    assert not res.converged
    assert all(not t["ok"] for t in res.trace)


def test_knee_search_budget_of_one_probes_full_resolution():
    """max_probes=1 (reachable via --search-budget 1) must spend its one
    probe at full resolution on rate0 instead of burning it on a bracket
    probe that can never certify a knee."""
    log = []
    res = KneeSearch(_synthetic_probe(1250.0, log), 10.0, rate0=800.0,
                     max_probes=1).run()
    assert log == [(800.0, "bisect")]
    assert res.knee_rps == pytest.approx(800.0)
    assert not res.converged        # no failing bound: lower bound only


def test_knee_search_sustainable_at_ceiling():
    def always_pass(rate, phase):
        return {"p99_ms": 1.0, "achieved_rps": rate,
                "completion_rps": rate, "completed_frac": 1.0,
                "rejected": 0}
    res = KneeSearch(always_pass, 10.0, rate0=1000.0, max_probes=10,
                     rate_ceiling=8000.0).run()
    assert res.knee_rps == pytest.approx(8000.0)


def test_knee_search_knee_must_be_certified_at_full_resolution():
    """A passing low-res bracket probe never becomes the knee: short
    windows under-sample the tail (a 0.2s probe of firecracker at 1.7x
    its knee reports p99 6ms where the full window reports ~1s)."""
    def optimistic_bracket(rate, phase):
        over = rate > 1000.0
        lying = phase == "bracket" and rate <= 1800.0   # short-window lie
        ok = (not over) or lying
        return {"p99_ms": 2.0 if ok else 900.0,
                "achieved_rps": min(rate, 1100.0),
                "completion_rps": min(rate, 1100.0),
                "completed_frac": 1.0 if ok else 0.5, "rejected": 0}
    res = KneeSearch(optimistic_bracket, 10.0, rate0=1500.0,
                     rel_tol=0.10, max_probes=14).run()
    assert res.knee_rps <= 1000.0
    idx = res.knee_trace_index()
    assert idx is not None and res.trace[idx]["phase"] == "bisect"


def test_knee_search_validates_parameters():
    probe = _synthetic_probe(1000.0)
    for kwargs in ({"growth": 1.0}, {"shrink": 1.0}, {"rel_tol": 0.0},
                   {"max_probes": 0}, {"rate_floor": 0.0},
                   {"rate_floor": 500.0, "rate_ceiling": 100.0}):
        with pytest.raises(ValueError):
            KneeSearch(probe, 10.0, **kwargs)


# ---------------------------------------------------------------------------
# Engine convergence against a dense grid on the real simulator.


def _sim_probe(backend, duration_s=0.4, seed=3):
    def probe(rate, phase):
        d = duration_s * (0.5 if phase == "bracket" else 1.0)
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend, n_cores=10)
        rt.deploy_blocking(FunctionSpec(name="aes", max_cores=8))
        return drive(rt, LoadSpec(PoissonArrivals(rate), ("aes",),
                                  weights=(1.0,), duration_s=d,
                                  warmup_frac=0.2))
    return probe


@pytest.mark.parametrize("backend,lo,hi", [("containerd", 700.0, 2400.0),
                                           ("junctiond", 7000.0, 24000.0)])
def test_knee_search_matches_dense_grid_knee(backend, lo, hi):
    """The search must land within tolerance of what a dense geometric
    grid over the same range finds — while issuing fewer open-loop runs
    than that grid (the whole point of bisection)."""
    probe = _sim_probe(backend)
    rates, r = [], lo
    while r <= hi:
        rates.append(r)
        r *= 1.12
    curve = []
    for rate in rates:
        row = probe(rate, "grid")
        row["nominal_rps"] = rate
        curve.append(row)
    grid_knee = knee_of_curve(curve, slo_p99_ms=10.0)
    assert grid_knee > 0
    res = KneeSearch(probe, slo_p99_ms=10.0, rate0=math.sqrt(lo * hi),
                     rel_tol=0.10, max_probes=12).run()
    assert res.converged
    assert res.knee_rps == pytest.approx(grid_knee, rel=0.15)
    assert res.n_probes < len(rates)


def test_open_loop_probe_is_deterministic_for_search():
    """Fixed (seed, rate) -> identical probe row, which makes the whole
    search deterministic for a given scenario + seed."""
    probe = _sim_probe("containerd")
    a, b = probe(900.0, "bisect"), probe(900.0, "bisect")
    a.pop("per_fn"), b.pop("per_fn")
    a.pop("latencies_ms"), b.pop("latencies_ms")
    assert a == b


# ---------------------------------------------------------------------------
# Satellite bugfix: an open-loop run must report the per-run rejected
# delta, not the runtime-lifetime counter.


def test_completed_frac_counts_admitted_arrivals_not_records():
    """``completed_frac`` must grade completions against every *admitted*
    request — the runtime's records only exist for completed invocations,
    so a record-based denominator would make the fraction identically 1.0
    and silently strip the admission guard from the search verdict."""
    light = _sim_probe("containerd")(600.0, "bisect")
    assert light["completed_frac"] == pytest.approx(1.0, abs=0.02)
    # deep overload on a short window: the backlog cannot drain, so a
    # visible share of admitted requests never completes
    sim = Simulator(seed=3)
    rt = FaasdRuntime(sim, backend="containerd", n_cores=10)
    rt.deploy_blocking(FunctionSpec(name="aes", max_cores=8))
    over = drive(rt, LoadSpec(PoissonArrivals(20000.0), ("aes",),
                              weights=(1.0,), duration_s=0.4,
                              warmup_frac=0.2))
    assert over["completed_frac"] < 0.9


def test_drive_reports_per_run_rejected_delta():
    sim = Simulator(seed=0)
    rt = FaasdRuntime(sim, backend="containerd", n_cores=4)
    rt.deploy_blocking(FunctionSpec(name="f"))
    first = drive(rt, LoadSpec.single("f", 2000.0, duration_s=0.2,
                                      warmup_s=0.05, max_outstanding=1))
    assert first["rejected"] > 0                # overload run saw rejects
    # same runtime reused at a trivial rate (exactly what knee-search
    # bracketing wants to do): the new run must report ITS OWN rejects
    second = drive(rt, LoadSpec.single("f", 50.0, duration_s=0.2,
                                       warmup_s=0.05))
    assert second["rejected"] == 0
    assert rt.rejected == first["rejected"]     # lifetime counter intact


# ---------------------------------------------------------------------------
# Satellite bugfix: knee row tracked by index, not float re-matching.


def test_knee_index_of_curve_matches_knee_of_curve():
    curve = [
        {"nominal_rps": 100.0, "offered_rps": 101.3, "achieved_rps": 99,
         "p99_ms": 2.0, "rejected": 0},
        {"nominal_rps": 197.3, "offered_rps": 196.1, "achieved_rps": 195,
         "p99_ms": 9.0, "rejected": 0},
        {"nominal_rps": 400.0, "offered_rps": 400, "achieved_rps": 399,
         "p99_ms": 50.0, "rejected": 0},
    ]
    assert knee_index_of_curve(curve, slo_p99_ms=10.0) == 1
    assert knee_of_curve(curve, slo_p99_ms=10.0) == 197.3
    assert knee_index_of_curve(curve, slo_p99_ms=1.0) is None
    assert knee_of_curve(curve, slo_p99_ms=1.0) == 0.0


def test_search_mode_artifact_tracks_knee_row_by_index():
    sc = dataclasses.replace(get_scenario("paper-fig6"),
                             backends=("containerd", "junctiond"))
    doc = ExperimentRunner(duration_scale=0.33, smoke=True).run_suite(
        [sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    validate_artifact(doc)
    for backend, res in doc["scenarios"][0]["backends"].items():
        search = res["search"]
        assert search["n_probes"] == len(res["curve"])
        assert search["knee_rps_per_seed"]
        assert search["trace"][0]["probes"]
        # the representative latency row IS the knee probe's row — with
        # search-generated rates a float re-match would silently miss
        idx = res["knee_row"]
        assert idx is not None
        rep = res["curve"][idx]
        assert res["median_ms"] == rep["median_ms"]
        assert res["p99_ms"] == rep["p99_ms"]
        if res["knee_rps"] > 0:
            assert rep["nominal_rps"] == pytest.approx(res["knee_rps"])
    # fig6 claims pick the baseline latency row through the same index
    claims = doc["scenarios"][0]["claims"]
    assert claims["throughput_ratio"]["measured"] > 1.0
    assert "median_speedup" in claims


def test_grid_mode_still_sweeps_pinned_rates():
    """Explicit ``rates`` keep the exact-reproduction grid path: no
    search block, the curve is exactly the pinned grid."""
    sc = Scenario(name="grid-unit", description="pinned grid",
                  mode="open",
                  functions=(FunctionProfile("aes", max_cores=8),),
                  rates={"containerd": (300.0, 600.0)},
                  duration_s=0.5, seeds=(0,), slo_p99_ms=10.0,
                  backends=("containerd",))
    assert sc.search_spec() is None
    doc = ExperimentRunner(smoke=True).run_suite([sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    res = doc["scenarios"][0]["backends"]["containerd"]
    assert "search" not in res
    assert [r["nominal_rps"] for r in res["curve"]] == [300.0, 600.0]
    assert res["knee_row"] is not None
    validate_artifact(doc)


def test_search_budget_ceiling_respected_by_runner():
    spec = SearchSpec(max_probes=3, smoke_max_probes=3)
    sc = dataclasses.replace(get_scenario("paper-fig6"), search=spec,
                             backends=("junctiond",))
    doc = ExperimentRunner(duration_scale=0.33, smoke=True).run_suite(
        [sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    res = doc["scenarios"][0]["backends"]["junctiond"]
    assert res["search"]["n_probes"] <= 3
    assert res["search"]["spec"]["max_probes"] == 3


# ---------------------------------------------------------------------------
# Satellite bugfix: warm-inflation guard in mixed mode.


def test_mixed_mode_flags_insufficient_warm_samples():
    """A warmup window that swallows the whole pre-storm phase leaves no
    'before' samples: the inflation ratio must come back flagged instead
    of as a silent NaN that poisons compare baselines."""
    sc = dataclasses.replace(get_scenario("mixed-cold-warm"),
                             warmup_frac=0.5, storm_functions=4,
                             backends=("junctiond",), autoscaler=None)
    doc = ExperimentRunner(duration_scale=0.2, smoke=True).run_suite(
        [sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    res = doc["scenarios"][0]["backends"]["junctiond"]
    assert res["insufficient_warm_samples"] >= 1
    assert math.isnan(res["warm_p99_inflation"])
    validate_artifact(doc)


def test_mixed_mode_healthy_run_is_unflagged():
    sc = dataclasses.replace(get_scenario("mixed-cold-warm"),
                             storm_functions=4,
                             backends=("junctiond",), autoscaler=None)
    doc = ExperimentRunner(duration_scale=0.33, smoke=True).run_suite(
        [sc], suite="unit")
    assert not doc["failures"], doc["failures"]
    res = doc["scenarios"][0]["backends"]["junctiond"]
    assert res["insufficient_warm_samples"] == 0
    assert res["warm_p99_inflation"] > 0


# ---------------------------------------------------------------------------
# Schema v4: search blocks validate; older versions never require them.


def test_schema_v4_validates_search_blocks():
    good = {"spec": {"rel_tol": 0.1}, "n_probes": 5,
            "knee_rps_per_seed": [1000.0], "converged": True,
            "trace": []}
    doc = build_artifact("unit", [{
        "name": "s", "mode": "open", "description": "d",
        "backend_set": ["containerd"],
        "backends": {"containerd": {"search": good}}}],
        [metric_row("m", 1.0, "d")], [])
    validate_artifact(doc)
    bad = build_artifact("unit", [{
        "name": "s", "mode": "open", "description": "d",
        "backend_set": ["containerd"],
        "backends": {"containerd": {"search": {"n_probes": 5}}}}], [], [])
    with pytest.raises(ValueError, match="search missing"):
        validate_artifact(bad)
    # pre-v4 documents never carry (or require) search blocks
    bad["schema_version"] = 3
    validate_artifact(bad)
