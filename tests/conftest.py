import os
import sys

# tests must see ONE device (the dry-run sets 512 only in its own entry
# point); make sure nothing leaked in.
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
