"""Event-heap driver equivalence: drive(engine="events") must be a
statistical stand-in for the generator reference engine.

The fast engine compresses the 14-segment invocation chain to 5 CPU
stations + 1 merged off-path job and draws all randomness in vectorized
batches, so the two engines consume the RNG differently — equivalence is
*statistical* (same-seed distributional agreement within tolerances),
while each engine on its own is byte-identical across same-seed runs.
"""
from pathlib import Path

import numpy as np
import pytest

from repro.core import (Autoscaler, BurstyArrivals, DiurnalArrivals,
                        FaasdRuntime, FunctionSpec, KneeSearch, LoadSpec,
                        PoissonArrivals, QueueDepthPolicy, Simulator,
                        TraceReplay, drive, heavy_tailed_work,
                        run_mixed_open_loop, run_open_loop)
from repro.core.simulator import EventLoop
from repro.core.workload import NullObserver

BACKENDS_AND_RATES = [
    ("containerd", 800.0),
    ("junctiond", 6000.0),
    ("quark", 700.0),
    ("wasm", 1100.0),
    ("firecracker", 800.0),
    ("gvisor", 800.0),
]


def _runtime(backend, seed=0, n_cores=10, **kw):
    sim = Simulator(seed=seed)
    rt = FaasdRuntime(sim, backend=backend, n_cores=n_cores, **kw)
    rt.deploy_blocking(FunctionSpec(name="aes"))
    return rt


def _both(backend, load, seed=0, observer=None, **kw):
    out = {}
    for engine in ("process", "events"):
        rt = _runtime(backend, seed=seed, **kw)
        out[engine] = drive(rt, load, observer=observer, engine=engine)
    return out["process"], out["events"]


def _assert_close(ref, fast):
    assert fast["n"] == ref["n"]                    # same arrival stream
    assert fast["rejected"] == ref["rejected"]
    assert fast["achieved_rps"] == pytest.approx(ref["achieved_rps"],
                                                 rel=0.02)
    assert fast["median_ms"] == pytest.approx(ref["median_ms"], rel=0.12)
    assert fast["p99_ms"] == pytest.approx(ref["p99_ms"], rel=0.35)
    assert fast["completed_frac"] == pytest.approx(ref["completed_frac"],
                                                   abs=0.03)


@pytest.mark.parametrize("backend,rate", BACKENDS_AND_RATES)
def test_engines_agree_poisson_all_backends(backend, rate):
    load = LoadSpec.single("aes", rate, duration_s=0.5)
    ref, fast = _both(backend, load, seed=3)
    _assert_close(ref, fast)


@pytest.mark.parametrize("arrivals", [
    PoissonArrivals(5000.0),
    BurstyArrivals(base_rps=1500.0, burst_rps=9000.0),
    DiurnalArrivals(mean_rate_rps=4000.0, period_s=0.5),
    TraceReplay(trace_s=tuple(np.linspace(0.0, 0.499, 2500))),
], ids=["poisson", "mmpp", "diurnal", "trace"])
def test_engines_agree_across_arrival_processes(arrivals):
    load = LoadSpec(arrivals=arrivals, functions=("aes",), duration_s=0.5)
    ref, fast = _both("junctiond", load, seed=5)
    _assert_close(ref, fast)


def test_engines_agree_under_overload():
    # deep overload: both engines must report the same collapse shape
    load = LoadSpec.single("aes", 20000.0, duration_s=0.4,
                           max_outstanding=2000)
    ref, fast = _both("containerd", load, seed=1)
    assert ref["completed_frac"] < 0.9
    assert fast["completed_frac"] < 0.9
    assert fast["completed_frac"] == pytest.approx(ref["completed_frac"],
                                                   abs=0.06)
    assert fast["completion_rps"] == pytest.approx(ref["completion_rps"],
                                                   rel=0.15)
    assert fast["rejected"] > 0 and ref["rejected"] > 0


def test_engines_agree_on_knee_location():
    def searcher(engine):
        def probe(rate, phase):
            rt = _runtime("containerd", seed=0)
            d = 0.2 if phase == "bracket" else 0.4
            return drive(rt, LoadSpec.single("aes", rate, duration_s=d),
                         engine=engine)
        return KneeSearch(probe, slo_p99_ms=10.0, rate0=1000.0).run()

    ref = searcher("process")
    fast = searcher("events")
    assert fast.knee_rps == pytest.approx(ref.knee_rps, rel=0.20)


def test_engines_agree_on_scale_event_stream():
    def run(engine):
        sim = Simulator(seed=7)
        rt = FaasdRuntime(sim, backend="junctiond", n_cores=10)
        rt.deploy_blocking(FunctionSpec(name="aes"))
        asc = Autoscaler(sim, rt, QueueDepthPolicy())
        asc.run()
        load = LoadSpec(arrivals=BurstyArrivals(base_rps=500.0,
                                                burst_rps=9000.0),
                        functions=("aes",), duration_s=1.0)
        drive(rt, load, observer=asc, engine=engine)
        return asc.telemetry()

    ref, fast = run("process"), run("events")
    for key in ("n_scale_events", "n_up", "n_down", "n_aborted",
                "cold_starts"):
        assert fast[key] == ref[key], key
    assert len(fast["reactions_ms"]) == len(ref["reactions_ms"])


def test_fast_engine_is_deterministic():
    def run():
        rt = _runtime("junctiond", seed=11)
        return drive(rt, LoadSpec.single("aes", 4000.0, duration_s=0.5))

    a, b = run(), run()
    assert a["latencies_ms"] == b["latencies_ms"]   # byte-identical
    flat_a = {k: v for k, v in a.items() if isinstance(v, (int, float))}
    flat_b = {k: v for k, v in b.items() if isinstance(v, (int, float))}
    assert flat_a == flat_b


def test_fast_engine_records_match_schema():
    rt = _runtime("junctiond", seed=2)
    res = drive(rt, LoadSpec.single("aes", 2000.0, duration_s=0.3))
    assert res["n"] > 0
    assert rt.records, "fast engine must append InvocationRecords"
    r = rt.records[-1]
    assert r.t_arrival < r.t_done
    assert r.t_start_exec <= r.t_end_exec <= r.t_done
    assert "aes" in res["per_fn"]
    assert res["per_fn"]["aes"].n == res["n"]


def test_uncached_resolve_falls_back_to_process_engine():
    # the fast engine compiles the cached-resolve chain only; a runtime
    # with the provider cache off must transparently take the generator
    # path (observable: per-request cache misses instead of hits)
    rt = _runtime("junctiond", seed=0, provider_cache=False)
    res = drive(rt, LoadSpec.single("aes", 500.0, duration_s=0.3),
                engine="events")
    assert res["n"] > 0
    assert rt.cache_misses > 0
    assert rt.cache_hits == 0


def test_drive_rejects_unknown_engine_and_function():
    rt = _runtime("junctiond")
    with pytest.raises(ValueError):
        drive(rt, LoadSpec.single("aes", 100.0), engine="threads")
    with pytest.raises(KeyError):
        drive(rt, LoadSpec.single("nope", 100.0))


def test_observer_sees_every_admitted_request():
    seen = {"arr": 0, "done": 0}

    class Counter:
        def on_arrival(self, fn):
            seen["arr"] += 1

        def on_done(self, fn):
            seen["done"] += 1

    rt = _runtime("junctiond", seed=4)
    res = drive(rt, LoadSpec.single("aes", 2000.0, duration_s=0.4),
                observer=Counter())
    assert seen["arr"] > 0
    assert seen["arr"] == seen["done"]              # moderate load drains
    assert isinstance(NullObserver(), object)       # default is a no-op
    assert res["rejected"] == 0


def test_legacy_shims_delegate_and_warn():
    rt = _runtime("junctiond", seed=6)
    with pytest.warns(DeprecationWarning):
        legacy = run_open_loop(rt, "aes", rate_rps=1500.0, duration_s=0.4)
    assert legacy["offered_rps"] == 1500.0          # nominal, as before
    assert legacy["n"] > 0

    rt2 = _runtime("junctiond", seed=6)
    with pytest.warns(DeprecationWarning):
        mixed = run_mixed_open_loop(rt2, ["aes"], [1.0],
                                    PoissonArrivals(1500.0), duration_s=0.4)
    assert mixed["n"] > 0
    for key in ("achieved_rps", "completion_rps", "median_ms", "p99_ms",
                "completed_frac", "rejected", "per_fn", "latencies_ms"):
        assert key in mixed, key


def test_shim_call_site_count_is_pinned():
    """The two calls above are the only shim call sites in the tree.

    simlint's deprecated-shim rule blocks new call sites in CI; this
    pin makes a stray one fail tier-1 even without the lint job.  If
    you added a call on purpose, don't bump the number — call
    ``drive(runtime, LoadSpec, ...)`` instead."""
    from repro.analysis.lint_rules import count_shim_call_sites
    root = Path(__file__).resolve().parent.parent
    assert count_shim_call_sites(
        ["src", "tests", "benchmarks"], root=root) == 2


def test_loadspec_validation_and_defaults():
    with pytest.raises(ValueError):
        LoadSpec(arrivals=PoissonArrivals(10.0), functions=())
    with pytest.raises(ValueError):
        LoadSpec(arrivals=PoissonArrivals(10.0), functions=("a",),
                 weights=(0.5, 0.5))
    spec = LoadSpec.single("aes", 100.0, duration_s=2.0)
    assert spec.effective_warmup_s == pytest.approx(0.4)
    abs_spec = LoadSpec.single("aes", 100.0, duration_s=2.0, warmup_s=0.3)
    assert abs_spec.effective_warmup_s == 0.3
    w = spec.normalized_weights()
    assert w.sum() == pytest.approx(1.0)


def test_loadspec_rejects_bad_weights():
    # negative weights would make rng.choice throw deep inside a run
    with pytest.raises(ValueError, match="non-negative"):
        LoadSpec(arrivals=PoissonArrivals(10.0), functions=("a", "b"),
                 weights=(0.5, -0.5))
    # an all-zero mix cannot be normalized into pick probabilities
    with pytest.raises(ValueError, match="positive sum"):
        LoadSpec(arrivals=PoissonArrivals(10.0), functions=("a", "b"),
                 weights=(0.0, 0.0))
    # zero weight for one function is fine while the sum stays positive
    spec = LoadSpec(arrivals=PoissonArrivals(10.0), functions=("a", "b"),
                    weights=(1.0, 0.0))
    assert spec.normalized_weights().sum() == pytest.approx(1.0)


def test_loadspec_rejects_empty_observation_window():
    with pytest.raises(ValueError, match="duration_s"):
        LoadSpec.single("aes", 100.0, duration_s=0.0)
    # warmup_s >= duration_s leaves nothing to observe
    with pytest.raises(ValueError, match="warmup_s"):
        LoadSpec.single("aes", 100.0, duration_s=0.2, warmup_s=0.3)
    with pytest.raises(ValueError, match="warmup_s"):
        LoadSpec.single("aes", 100.0, duration_s=0.2, warmup_s=0.2)
    with pytest.raises(ValueError, match="warmup_s"):
        LoadSpec.single("aes", 100.0, duration_s=0.2, warmup_s=-0.1)
    with pytest.raises(ValueError, match="warmup_frac"):
        LoadSpec.single("aes", 100.0, duration_s=1.0, warmup_frac=1.0)
    with pytest.raises(ValueError, match="warmup_frac"):
        LoadSpec.single("aes", 100.0, duration_s=1.0, warmup_frac=-0.2)
    # boundary: warmup_s just inside the window is accepted
    ok = LoadSpec.single("aes", 100.0, duration_s=0.2, warmup_s=0.19)
    assert ok.effective_warmup_s == pytest.approx(0.19)


def test_heavy_tailed_work_batch_sampler():
    rng = np.random.default_rng(0)
    sampler = heavy_tailed_work(rng, median_us=95.0, cap_mult=10.0)
    batch = sampler.sample(20000)
    assert batch.shape == (20000,)
    assert float(np.median(batch)) == pytest.approx(95.0, rel=0.05)
    assert batch.max() <= 95.0 * 10.0 + 1e-9
    # scalar and batch draws come from the same distribution
    scalars = np.array([sampler() for _ in range(20000)])
    assert float(np.median(scalars)) == pytest.approx(95.0, rel=0.05)
    # deterministic under a fixed seed
    a = heavy_tailed_work(np.random.default_rng(1), 95.0).sample(100)
    b = heavy_tailed_work(np.random.default_rng(1), 95.0).sample(100)
    assert np.array_equal(a, b)


def test_event_loop_merges_arrivals_in_time_order():
    sim = Simulator(seed=0)
    order = []
    sim._schedule(0.15, order.append, ("heap", 0.15))
    sim._schedule(0.25, order.append, ("heap", 0.25))
    loop = EventLoop(sim)
    n = loop.run(1.0, [0.1, 0.2, 0.3],
                 lambda i, t: order.append(("arrival", t)))
    assert n == 3
    assert order == [("arrival", 0.1), ("heap", 0.15), ("arrival", 0.2),
                     ("heap", 0.25), ("arrival", 0.3)]
    assert sim.now == 1.0                           # clock lands on `until`


def test_event_loop_stops_delivering_past_until():
    sim = Simulator(seed=0)
    seen = []
    loop = EventLoop(sim)
    n = loop.run(0.5, [0.1, 0.4, 0.7], lambda i, t: seen.append(t))
    assert n == 2 and seen == [0.1, 0.4]
    # the undelivered arrival stays undelivered; heap events beyond
    # `until` stay queued (Simulator.run semantics)
    assert sim.now == 0.5


def test_mixed_function_load_routes_by_weights():
    sim = Simulator(seed=9)
    rt = FaasdRuntime(sim, backend="junctiond", n_cores=10)
    rt.deploy_blocking(FunctionSpec(name="a", work_us=80.0))
    rt.deploy_blocking(FunctionSpec(name="b", work_us=400.0))
    load = LoadSpec(arrivals=PoissonArrivals(2000.0), functions=("a", "b"),
                    weights=(0.8, 0.2), duration_s=0.5)
    res = drive(rt, load)
    assert set(res["per_fn"]) == {"a", "b"}
    assert res["per_fn"]["a"].n > 2 * res["per_fn"]["b"].n
    assert res["per_fn"]["b"].median_ms > res["per_fn"]["a"].median_ms
