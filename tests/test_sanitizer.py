"""Runtime sim-sanitizer: equivalence, trip conditions, env-var hook.

The sanitizer's contract is twofold: (1) with checks installed, every
engine produces *byte-identical* results to an unchecked run (the
checked loops are operation-for-operation copies); (2) deliberately
corrupted simulator state trips :class:`SimCheckError` instead of
silently skewing results.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

import repro.core.workload as workload
from repro.analysis import sanitizer
from repro.analysis.sanitizer import SimCheckError
from repro.core import (EventLoop, FaasdRuntime, FunctionSpec, LoadSpec,
                        Simulator, drive)
from repro.core.simulator import EventLoop as _EventLoop

REPO_ROOT = Path(__file__).resolve().parent.parent


@pytest.fixture
def sanitized():
    """Install the checked wrappers for one test, always restoring."""
    sanitizer.install()
    try:
        yield
    finally:
        sanitizer.uninstall()


def _runtime(n_cores=8, backend="junctiond", seed=7):
    sim = Simulator(seed=seed)
    rt = FaasdRuntime(sim, backend=backend, n_cores=n_cores)
    rt.deploy_blocking(FunctionSpec(name="aes"))
    return sim, rt


def _drive_fingerprint(engine, backend="junctiond", n_cores=8,
                       rate=4000.0):
    _, rt = _runtime(n_cores=n_cores, backend=backend)
    res = drive(rt, LoadSpec.single("aes", rate, duration_s=0.5),
                engine=engine)
    return json.dumps(res, sort_keys=True, default=str)


def _fleet_fingerprint():
    from repro.fleet import Cluster
    sim = Simulator(seed=3)
    cl = Cluster(sim, n_workers=4, backend="junctiond", n_cores=8)
    cl.deploy_blocking(FunctionSpec(name="aes"))
    res = drive(cl, LoadSpec.single("aes", 6000.0, duration_s=0.5))
    return json.dumps(res, sort_keys=True, default=str)


# ---------------------------------------------------------------------------
# byte-identical equivalence


@pytest.mark.parametrize("engine", ["events", "process"])
@pytest.mark.parametrize("backend", ["junctiond", "containerd"])
def test_checked_run_is_byte_identical(engine, backend):
    base = _drive_fingerprint(engine, backend=backend)
    sanitizer.install()
    try:
        checked = _drive_fingerprint(engine, backend=backend)
    finally:
        sanitizer.uninstall()
    assert checked == base


def test_checked_run_is_byte_identical_under_contention():
    # few cores + high rate exercises the waiter queue, materialize,
    # and the per-station fallback alongside the fused path
    base = _drive_fingerprint("events", n_cores=3, rate=20000.0)
    sanitizer.install()
    try:
        checked = _drive_fingerprint("events", n_cores=3, rate=20000.0)
    finally:
        sanitizer.uninstall()
    assert checked == base


def test_checked_fleet_run_is_byte_identical():
    base = _fleet_fingerprint()
    sanitizer.install()
    try:
        checked = _fleet_fingerprint()
    finally:
        sanitizer.uninstall()
    assert checked == base


# ---------------------------------------------------------------------------
# install/uninstall mechanics


def test_install_uninstall_restore_originals():
    orig_loop_run = _EventLoop.run
    orig_sim_run = Simulator.run
    assert workload.SIM_CHECK is False
    assert not sanitizer.enabled()
    sanitizer.install()
    try:
        assert sanitizer.enabled()
        assert workload.SIM_CHECK is True
        assert _EventLoop.run is not orig_loop_run
    finally:
        sanitizer.uninstall()
    assert not sanitizer.enabled()
    assert workload.SIM_CHECK is False
    assert _EventLoop.run is orig_loop_run
    assert Simulator.run is orig_sim_run


def test_install_is_idempotent():
    sanitizer.install()
    try:
        checked = _EventLoop.run
        sanitizer.install()
        assert _EventLoop.run is checked
    finally:
        sanitizer.uninstall()
    sanitizer.uninstall()       # second uninstall is a no-op


# ---------------------------------------------------------------------------
# trip conditions


def test_corrupted_busy_over_capacity_trips(sanitized):
    _, rt = _runtime(n_cores=4)
    pool = rt.cores
    with pytest.raises(SimCheckError, match="past capacity"):
        pool.busy = pool.n_cores + 5


def test_corrupted_busy_negative_trips(sanitized):
    _, rt = _runtime(n_cores=4)
    pool = rt.cores
    with pytest.raises(SimCheckError, match="negative"):
        pool.busy = -1


def test_release_at_with_waiters_trips(sanitized):
    sim, rt = _runtime(n_cores=4)
    pool = rt.cores
    pool._waiters.append(sim.event())
    with pytest.raises(SimCheckError, match="no-waiters"):
        pool.release_at(sim.now + 1.0)


def test_release_at_in_the_past_trips(sanitized):
    sim, rt = _runtime(n_cores=4)
    sim.now = 10.0
    with pytest.raises(SimCheckError, match="past"):
        rt.cores.release_at(5.0)


def test_waiter_append_with_pending_releases_trips(sanitized):
    sim, rt = _runtime(n_cores=4)
    pool = rt.cores
    pool.busy = 1
    pool.release_at(sim.now + 1.0)      # legal: no waiters yet
    with pytest.raises(SimCheckError, match="_materialize"):
        pool._waiters.append(sim.event())


def test_negative_delay_trips(sanitized):
    sim = Simulator(seed=0)
    with pytest.raises(SimCheckError, match="negative delay"):
        sim._schedule(-0.5, lambda: None)


def test_event_in_the_past_trips(sanitized):
    import heapq
    sim = Simulator(seed=0)
    sim.now = 5.0
    heapq.heappush(sim._heap, (1.0, 0, lambda: None, ()))
    with pytest.raises(SimCheckError, match="clock"):
        EventLoop(sim).run(10.0)
    sim2 = Simulator(seed=0)
    sim2.now = 5.0
    heapq.heappush(sim2._heap, (1.0, 0, lambda: None, ()))
    with pytest.raises(SimCheckError, match="clock"):
        sim2.run(10.0)


def test_backwards_arrival_stream_trips(sanitized):
    sim = Simulator(seed=0)
    with pytest.raises(SimCheckError, match="backwards"):
        EventLoop(sim).run(10.0, [5.0, 1.0], lambda i, t: None)


def test_fused_admit_check_trips_on_contention(sanitized):
    sim, rt = _runtime(n_cores=4)
    pool = rt.cores
    pool._waiters.append(sim.event())
    with pytest.raises(SimCheckError, match="waiters"):
        sanitizer.fused_admit_check(pool, 1.0, 2.0)


def test_fused_admit_check_trips_on_past_completion(sanitized):
    _, rt = _runtime(n_cores=4)
    with pytest.raises(SimCheckError, match="precedes"):
        sanitizer.fused_admit_check(rt.cores, 1.0, 0.5)
    with pytest.raises(SimCheckError, match="off-path"):
        sanitizer.fused_admit_check(rt.cores, 1.0, 2.0, off_end_t=0.5)


def test_monotone_run_passes_checks(sanitized):
    # a normal checked run completes without tripping anything
    _, rt = _runtime(n_cores=8)
    res = drive(rt, LoadSpec.single("aes", 2000.0, duration_s=0.3))
    assert res["n"] > 0


# ---------------------------------------------------------------------------
# REPRO_SIM_CHECK=1 env hook


def test_env_var_installs_sanitizer_on_core_import():
    env = dict(os.environ, REPRO_SIM_CHECK="1",
               PYTHONPATH=str(REPO_ROOT / "src"))
    code = (
        "import repro.core\n"
        "import repro.core.workload as w\n"
        "from repro.analysis import sanitizer\n"
        "assert sanitizer.enabled()\n"
        "assert w.SIM_CHECK is True\n"
        "from repro.core import Simulator, FaasdRuntime, FunctionSpec, "
        "LoadSpec, drive\n"
        "sim = Simulator(seed=1)\n"
        "rt = FaasdRuntime(sim, backend='junctiond', n_cores=8)\n"
        "rt.deploy_blocking(FunctionSpec(name='aes'))\n"
        "res = drive(rt, LoadSpec.single('aes', 1000.0, duration_s=0.2))\n"
        "assert res['n'] > 0\n"
        "print('ok')\n")
    rc = subprocess.run([sys.executable, "-c", code], env=env,
                        cwd=REPO_ROOT, capture_output=True, text=True)
    assert rc.returncode == 0, rc.stderr
    assert rc.stdout.strip() == "ok"


def test_env_var_absent_leaves_sim_unchecked():
    env = {k: v for k, v in os.environ.items() if k != "REPRO_SIM_CHECK"}
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    code = (
        "import repro.core\n"
        "from repro.analysis import sanitizer\n"
        "assert not sanitizer.enabled()\n"
        "import repro.core.workload as w\n"
        "assert w.SIM_CHECK is False\n"
        "print('ok')\n")
    rc = subprocess.run([sys.executable, "-c", code], env=env,
                        cwd=REPO_ROOT, capture_output=True, text=True)
    assert rc.returncode == 0, rc.stderr
