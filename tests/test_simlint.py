"""simlint: per-rule good/bad fixtures, pragma semantics, CLI contract.

Every rule gets at least one failing and one passing fixture (rules
with zero in-repo violations are still exercised here), written into a
tmp tree shaped like the repo (``src/repro/core/...``) so path-scoped
rules fire.  The suite also pins the CLI's exit-code semantics and the
``--list`` registry output, and checks the real tree is clean.
"""
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis.lint import main as lint_main
from repro.analysis.lint_engine import run_lint
from repro.analysis.lint_rules import RULES

REPO_ROOT = Path(__file__).resolve().parent.parent


def lint_snippet(tmp_path, relpath, source, rules=None):
    """Write ``source`` at ``relpath`` under a repo-shaped tmp tree and
    lint it; returns the findings."""
    target = tmp_path / relpath
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(source)
    return run_lint([relpath], root=str(tmp_path), rule_ids=rules)


def rule_ids_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# rule registry


def test_registry_has_the_contract_rules():
    expected = {"wall-clock", "unordered-iter", "registry-reachable",
                "float-eq", "deprecated-shim", "frozen-setattr",
                "sched-past", "spec-kwargs"}
    assert expected <= set(RULES)


def test_every_rule_has_doc_and_id():
    for rid, rule in RULES.items():
        assert rule.id == rid
        assert rule.summary, f"rule {rid} has no docstring"


# ---------------------------------------------------------------------------
# rule 1: wall-clock


BAD_WALL = """\
import time

def f():
    return time.time()
"""

GOOD_WALL = """\
def f(sim):
    return sim.now
"""


def test_wall_clock_bad(tmp_path):
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", BAD_WALL)
    assert rule_ids_of(findings) == {"wall-clock"}
    assert findings[0].line == 4


def test_wall_clock_variants(tmp_path):
    for src in (
        "from time import perf_counter\nperf_counter()\n",
        "import random\n",
        "from random import random\n",
        "import uuid\n",
        "from datetime import datetime\ndatetime.now()\n",
        "import datetime\ndatetime.datetime.now()\n",
    ):
        findings = lint_snippet(tmp_path, "src/repro/fleet/x.py", src)
        assert "wall-clock" in rule_ids_of(findings), src


def test_wall_clock_good(tmp_path):
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", GOOD_WALL)
    assert findings == []


def test_wall_clock_out_of_scope_paths_ignored(tmp_path):
    # the JAX serving stack measures real host time by design
    findings = lint_snippet(tmp_path, "src/repro/serving/x.py", BAD_WALL)
    assert findings == []


# ---------------------------------------------------------------------------
# rule 2: unordered iteration


def test_unordered_iter_bad(tmp_path):
    for src in (
        "for x in {1, 2, 3}:\n    pass\n",
        "for x in set(items):\n    pass\n",
        "ys = [f(x) for x in names.intersection(live)]\n",
        "h = hash(name)\n",
        "xs.sort(key=id)\n",
    ):
        findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
        assert "unordered-iter" in rule_ids_of(findings), src


def test_unordered_iter_good(tmp_path):
    for src in (
        "for x in sorted({1, 2, 3}):\n    pass\n",
        "for x in sorted(set(items)):\n    pass\n",
        "import zlib\nh = zlib.crc32(name.encode())\n",
    ):
        findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
        assert findings == [], src


def test_unordered_iter_only_in_sim_paths(tmp_path):
    findings = lint_snippet(
        tmp_path, "src/repro/experiments/x.py",
        "for x in {1, 2}:\n    pass\n")
    assert findings == []


# ---------------------------------------------------------------------------
# rule 3: registry reachability (cross-file)


REGISTRY_DEF = """\
_BUILTIN_MODULES = (
    "repro.core.good",
)
"""

REGISTERED = """\
from repro.core.backends import register_backend

@register_backend
class Thing:
    name = "thing"
"""


def test_registry_reachable_bad(tmp_path):
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "src/repro/core/backends.py").write_text(REGISTRY_DEF)
    (tmp_path / "src/repro/core/stray.py").write_text(REGISTERED)
    findings = run_lint(["src"], root=str(tmp_path),
                        rule_ids=["registry-reachable"])
    assert [f.rule for f in findings] == ["registry-reachable"]
    assert "repro.core.stray" in findings[0].message


def test_registry_reachable_good(tmp_path):
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "src/repro/core/backends.py").write_text(
        '_BUILTIN_MODULES = (\n    "repro.core.good",\n)\n')
    (tmp_path / "src/repro/core/good.py").write_text(REGISTERED)
    findings = run_lint(["src"], root=str(tmp_path),
                        rule_ids=["registry-reachable"])
    assert findings == []


def test_registry_reachable_fleet_init(tmp_path):
    pkg = tmp_path / "src/repro/fleet"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("from repro.fleet import placement\n")
    (pkg / "placement.py").write_text(
        "from repro.fleet.registry import register_placement\n\n"
        "@register_placement\nclass RR:\n    name = 'rr'\n")
    (pkg / "stray.py").write_text(
        "from repro.fleet.registry import register_distribution\n\n"
        "@register_distribution\nclass Tree:\n    name = 'tree'\n")
    findings = run_lint(["src"], root=str(tmp_path),
                        rule_ids=["registry-reachable"])
    assert [f.rule for f in findings] == ["registry-reachable"]
    assert "repro.fleet.stray" in findings[0].message


# ---------------------------------------------------------------------------
# rule 4: float equality


def test_float_eq_bad(tmp_path):
    for src in (
        "hit = rate == knee\n",
        "if row_rps == 128.0:\n    pass\n",
        "same = t0 != t1\n",
        'match = row["nominal_rps"] == rate\n',
    ):
        findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
        assert "float-eq" in rule_ids_of(findings), src


def test_float_eq_good(tmp_path):
    for src in (
        "hit = abs(rate - knee) < 1e-9\n",
        "done = count == 0\n",          # int compare: fine
        "ok = name == 'aes'\n",
        "if rate > knee:\n    pass\n",
    ):
        findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
        assert findings == [], src


# ---------------------------------------------------------------------------
# rule 5: deprecated shims


def test_deprecated_shim_bad(tmp_path):
    for src in (
        "res = run_open_loop(rt, 'aes', 100.0)\n",
        "from repro.core import run_mixed_open_loop\n",
        "w.run_mixed_open_loop(rt, {})\n",
    ):
        findings = lint_snippet(tmp_path, "src/repro/core/new.py", src)
        assert "deprecated-shim" in rule_ids_of(findings), src


def test_deprecated_shim_exempt_files(tmp_path):
    findings = lint_snippet(
        tmp_path, "tests/test_event_loop.py",
        "res = run_open_loop(rt, 'aes', 100.0)\n")
    assert findings == []


def test_deprecated_shim_good(tmp_path):
    findings = lint_snippet(
        tmp_path, "src/repro/core/new.py",
        "res = drive(rt, load)\n")
    assert findings == []


# ---------------------------------------------------------------------------
# rule 6: frozen-dataclass mutation


def test_frozen_setattr_bad(tmp_path):
    src = ("def tweak(spec, rate):\n"
           "    object.__setattr__(spec, 'rate_rps', rate)\n")
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
    assert rule_ids_of(findings) == {"frozen-setattr"}


def test_frozen_setattr_good(tmp_path):
    src = ("class Spec:\n"
           "    def __post_init__(self):\n"
           "        object.__setattr__(self, 'functions', ())\n")
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
    assert findings == []


# ---------------------------------------------------------------------------
# rule 7: scheduling into the past


def test_sched_past_bad(tmp_path):
    for src in (
        "sim._schedule(-0.5, cb)\n",
        "sim._schedule(sim.now + 0.1, cb)\n",      # absolute, not delay
        "sim.timeout(t0 + now)\n",
    ):
        findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
        assert "sched-past" in rule_ids_of(findings), src


def test_sched_past_good(tmp_path):
    for src in (
        "sim._schedule(t - sim.now, cb)\n",
        "sim._schedule(avail_t - now, cb)\n",
        "sim.timeout(t0 + rel_t - sim.now)\n",
        "sim.timeout(0.25)\n",
        "sim.timeout(max(0.0, t - sim.now))\n",    # opaque call: no claim
    ):
        findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
        assert findings == [], src


# ---------------------------------------------------------------------------
# rule 8: spec kwargs (cross-file)


SPEC_DEF = """\
import dataclasses

@dataclasses.dataclass(frozen=True)
class LoadSpec:
    arrivals: object
    functions: tuple
    duration_s: float = 2.0

    @classmethod
    def single(cls, fn_name, rate_rps, **kw):
        return cls(arrivals=None, functions=(fn_name,), **kw)
"""


def _spec_tree(tmp_path, use_src):
    (tmp_path / "src/repro/core").mkdir(parents=True)
    (tmp_path / "src/repro/core/workload.py").write_text(SPEC_DEF)
    (tmp_path / "src/repro/core/user.py").write_text(use_src)
    return run_lint(["src"], root=str(tmp_path), rule_ids=["spec-kwargs"])


def test_spec_kwargs_bad(tmp_path):
    findings = _spec_tree(
        tmp_path,
        "from repro.core.workload import LoadSpec\n"
        "spec = LoadSpec(arrivals=None, functions=('aes',),\n"
        "                durration_s=2.0)\n")
    assert [f.rule for f in findings] == ["spec-kwargs"]
    assert "durration_s" in findings[0].message


def test_spec_kwargs_classmethod_forwarding(tmp_path):
    bad = _spec_tree(
        tmp_path,
        "from repro.core.workload import LoadSpec\n"
        "spec = LoadSpec.single('aes', 100.0, duratoin_s=1.0)\n")
    assert [f.rule for f in bad] == ["spec-kwargs"]


def test_spec_kwargs_good(tmp_path):
    findings = _spec_tree(
        tmp_path,
        "from repro.core.workload import LoadSpec\n"
        "spec = LoadSpec(arrivals=None, functions=('aes',),\n"
        "                duration_s=1.0)\n"
        "also = LoadSpec.single('aes', 100.0, duration_s=1.0)\n")
    assert findings == []


# ---------------------------------------------------------------------------
# pragmas


def test_pragma_suppresses_trailing(tmp_path):
    src = ("import time\n"
           "t0 = time.time()  # simlint: allow[wall-clock] measures host\n")
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
    assert findings == []


def test_pragma_suppresses_preceding_comment_line(tmp_path):
    src = ("import time\n"
           "# simlint: allow[wall-clock] measures host elapsed\n"
           "t0 = time.time()\n")
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
    assert findings == []


def test_pragma_without_reason_is_rejected(tmp_path):
    src = ("import time\n"
           "t0 = time.time()  # simlint: allow[wall-clock]\n")
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
    rules = rule_ids_of(findings)
    # the suppression must NOT take effect, and the pragma itself is
    # reported
    assert "wall-clock" in rules
    assert "pragma" in rules
    assert any("reason" in f.message for f in findings)


def test_pragma_unknown_rule_and_verb_rejected(tmp_path):
    # the pragma text is assembled at runtime so this file's own lines
    # don't scan as (broken) pragmas when the real tree is linted
    src = ("x = 1  # simlint" ": allow[no-such-rule] because\n"
           "y = 2  # simlint" ": ignore[wall-clock] because\n")
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
    msgs = " | ".join(f.message for f in findings)
    assert "unknown rule" in msgs
    assert "verb" in msgs


def test_pragma_only_suppresses_named_rule(tmp_path):
    src = ("import time\n"
           "t0 = time.time()  # simlint: allow[float-eq] wrong rule id\n")
    findings = lint_snippet(tmp_path, "src/repro/core/x.py", src)
    assert "wall-clock" in rule_ids_of(findings)


# ---------------------------------------------------------------------------
# CLI contract


def test_cli_list_exits_zero(capsys):
    assert lint_main(["--list"]) == 0
    out = capsys.readouterr().out
    for rid in RULES:
        assert rid in out


def test_cli_no_paths_is_usage_error():
    assert lint_main([]) == 2


def test_cli_unknown_rule_is_usage_error(tmp_path):
    (tmp_path / "x.py").write_text("pass\n")
    assert lint_main(["x.py", "--root", str(tmp_path),
                      "--rules", "no-such-rule"]) == 2


def test_cli_findings_exit_one_and_print_rule_and_location(
        tmp_path, capsys):
    target = tmp_path / "src/repro/core/x.py"
    target.parent.mkdir(parents=True)
    target.write_text(BAD_WALL)
    rc = lint_main(["src", "--root", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "src/repro/core/x.py:4: [wall-clock]" in out


def test_cli_clean_tree_exits_zero(tmp_path, capsys):
    target = tmp_path / "src/repro/core/x.py"
    target.parent.mkdir(parents=True)
    target.write_text(GOOD_WALL)
    assert lint_main(["src", "--root", str(tmp_path)]) == 0
    assert capsys.readouterr().out == ""


def test_syntax_error_is_reported_not_raised(tmp_path):
    findings = lint_snippet(tmp_path, "src/repro/core/x.py",
                            "def broken(:\n")
    assert [f.rule for f in findings] == ["pragma"]
    assert "syntax error" in findings[0].message


# ---------------------------------------------------------------------------
# the real tree


def test_real_tree_is_clean():
    env = dict(os.environ, PYTHONPATH=str(REPO_ROOT / "src"))
    rc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint",
         "src", "tests", "benchmarks"],
        cwd=REPO_ROOT, capture_output=True, text=True, env=env,
    )
    assert rc.returncode == 0, rc.stdout + rc.stderr
