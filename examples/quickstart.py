"""Quickstart: deploy the paper's AES(600 B) function on every registered
execution backend and invoke it 100 times — the Fig 5 experiment, widened
to the full backend matrix, in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (FaasdRuntime, FunctionSpec, LatencySummary,
                        Simulator, available_backends, run_sequential)

for backend in available_backends():
    sim = Simulator(seed=0)
    runtime = FaasdRuntime(sim, backend=backend)
    runtime.deploy_blocking(FunctionSpec(name="aes"))     # vSwarm AES, 600 B
    summary = run_sequential(runtime, "aes", n=100)
    execs = LatencySummary.of(runtime.exec_latencies_ms())
    print(f"{backend:11s}: e2e median={summary.median_ms:.3f} ms "
          f"p99={summary.p99_ms:.3f} ms | exec median={execs.median_ms:.3f} ms")

print("\npaper (Fig 5): junctiond cuts median 37.33% and P99 63.42%")
