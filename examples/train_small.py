"""Train a ~100M-parameter dense model for a few hundred steps on the
synthetic-but-learnable LM stream (assignment's end-to-end training driver).

    PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses

from repro.config import ArchConfig, ArchType
from repro.train import AdamWConfig, DataConfig, SyntheticLM, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--seq-len", type=int, default=256)
ap.add_argument("--batch", type=int, default=8)
args = ap.parse_args()

# ~100M params: 12L, d=768, llama-style (GQA 12/4 heads, SwiGLU)
cfg = ArchConfig(
    name="demo-100m", arch_type=ArchType.DENSE, citation="[this-repo]",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
    vocab_size=32000, dtype="float32")
print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
      f"{args.steps} steps @ batch {args.batch} x seq {args.seq_len}")

dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                batch_size=args.batch, seed=0)
res = train(cfg, SyntheticLM(dc).batches(), steps=args.steps,
            opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=20,
                                total_steps=args.steps),
            log_every=20, checkpoint_path="/tmp/demo100m.npz",
            checkpoint_every=100)
h = res["history"]
print(f"\nloss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}; "
      f"checkpoint at /tmp/demo100m.npz")
