"""End-to-end serving driver (the paper is a serving paper, so this is the
required e2e example): a REAL reduced qwen3 model served with batched
requests through the full junctiond pipeline —

  continuous batcher -> prefill -> decode loop (real JAX compute on CPU)
  measured per-step service times -> junctiond vs containerd invocation
  path -> latency report.

    PYTHONPATH=src python examples/serve_e2e.py
"""
import dataclasses

from repro.config import get_arch, reduced
from repro.core import FaasdRuntime, FunctionSpec, Simulator, run_sequential
from repro.serving import ServingEngine

cfg = dataclasses.replace(reduced(get_arch("qwen3-1.7b")), dtype="float32")
print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model}, qk_norm={cfg.qk_norm})")

# 1) real model serving: batched requests through the continuous batcher
engine = ServingEngine(cfg, batch_slots=4, max_seq_len=48)
prompts = [[1, 2, 3, 4], [5, 6, 7, 8], [2, 4, 6, 8], [9, 7, 5, 3]]
outs = engine.generate(prompts, max_new_tokens=8)
print(f"generated {sum(len(o) for o in outs)} tokens across {len(outs)} requests")
svc_us = engine.mean_decode_step_us()
print(f"measured decode step: {svc_us:.0f} us (CPU, reduced model)")

# 2) deploy the endpoint as a junctiond function; drive the FaaS path
for backend in ("containerd", "junctiond"):
    sim = Simulator(seed=1)
    rt = FaasdRuntime(sim, backend=backend)
    rt.deploy_blocking(FunctionSpec(name="qwen3", work_us=svc_us,
                                    payload_bytes=2048, response_bytes=4096))
    s = run_sequential(rt, "qwen3", n=50)
    overhead_pct = 100 * (s.median_ms - svc_us / 1e3) / s.median_ms
    print(f"{backend:11s}: e2e median={s.median_ms:.3f} ms "
          f"(runtime overhead {overhead_pct:.1f}% of e2e), p99={s.p99_ms:.3f} ms")
