"""Reproduce the paper's Fig 6 load sweep + the §3 polling-efficiency
argument in one script.

    PYTHONPATH=src python examples/faas_comparison.py
"""
from repro.core import (FaasdRuntime, FunctionSpec, LoadSpec, Simulator,
                        drive)

print("open-loop load sweep (AES 600B), p99 vs offered rps:\n")
print(f"{'rate':>8} | {'containerd p99 (ms)':>20} | {'junctiond p99 (ms)':>19}")
for rate in (500, 1000, 1500, 4000, 8000, 12000):
    row = [f"{rate:8d}"]
    for backend in ("containerd", "junctiond"):
        sim = Simulator(seed=3)
        rt = FaasdRuntime(sim, backend=backend)
        rt.deploy_blocking(FunctionSpec(name="aes", max_cores=8))
        res = drive(rt, LoadSpec.single("aes", rate, duration_s=1.0))
        val = res["p99_ms"]
        row.append(f"{val:20.2f}" if val == val else f"{'collapsed':>20}")
    print(" | ".join(row))

print("\npaper: junctiond sustains ~10x the throughput at ~3.5x lower tail")

# polling efficiency: cores left for real work on a 36-core server
from repro.core import JunctionInstance, PollingModel
from repro.core.latency import JUNCTION_RUNTIME
from repro.core.resources import CorePool
from repro.core.scheduler import JunctionScheduler

print("\ncores left for function work (36-core server):")
for n in (8, 32, 1000):
    rows = []
    for model in (PollingModel.CENTRALIZED, PollingModel.PER_INSTANCE):
        sim = Simulator()
        pool = CorePool(sim, 36, JUNCTION_RUNTIME)
        sched = JunctionScheduler(sim, pool, model)
        for i in range(n):
            inst = JunctionInstance(sim, f"f{i}")
            sched.register(inst)
            if pool.n_cores <= 0:
                break
        rows.append(pool.n_cores)
    print(f"  {n:5d} functions: centralized={rows[0]:2d}  per-instance(DPDK)={rows[1]:2d}")
