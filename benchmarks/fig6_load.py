"""Fig 6 reproduction: response time at varying offered load (open-loop
Poisson), containerd vs junctiond.

Paper claims: junctiond sustains ~10x more throughput while lowering
median latency ~2x and the tail ~3.5x.

Thin adapter over the ``paper-fig6`` scenario; sweep execution, knee/SLO
detection, and claim deltas live in :mod:`repro.experiments.runner`.
"""
from __future__ import annotations

from repro.experiments import ExperimentRunner, get_scenario

DEFAULT_DURATION_S = 1.5


def run(verbose=True, duration_s=DEFAULT_DURATION_S):
    sc = get_scenario("paper-fig6")
    doc = ExperimentRunner(
        duration_scale=duration_s / sc.duration_s).run_suite([sc],
                                                             suite="fig6")
    if doc["failures"]:
        raise RuntimeError(doc["failures"][0]["error"])
    entry = doc["scenarios"][0]
    claims = entry["claims"]
    if verbose:
        print("# fig6: open-loop load sweep (p99 SLO %.0fms)" % sc.slo_p99_ms)
        for name in ("containerd", "junctiond"):
            res = entry["backends"][name]
            print(f"  {name}:")
            for r in res["curve"]:
                print(f"    rate={r['nominal_rps']:6.0f} "
                      f"achieved={r['achieved_rps']:8.0f} "
                      f"median={r['median_ms']:8.2f}ms p99={r['p99_ms']:9.2f}ms")
        c_knee = claims["baseline_knee_rps"]["measured"]
        j_knee = claims["treatment_knee_rps"]["measured"]
        print(f"  sustainable: containerd={c_knee:.0f} rps, "
              f"junctiond={j_knee:.0f} rps "
              f"-> {claims['throughput_ratio']['measured']:.1f}x (paper: ~10x)")
        if "median_speedup" in claims:
            print(f"  at-load latency: median "
                  f"{claims['median_speedup']['measured']:.2f}x lower "
                  f"(paper ~2x), p99 "
                  f"{claims['p99_speedup']['measured']:.2f}x lower "
                  f"(paper ~3.5x)")
    rows = [(m["name"], m["value"], m["derived"]) for m in doc["metrics"]
            if m["name"].startswith("fig6_")]
    knees = {b: entry["backends"][b]["knee_rps"]
             for b in ("containerd", "junctiond")}
    return rows, {"containerd": entry["backends"]["containerd"]["curve"],
                  "junctiond": entry["backends"]["junctiond"]["curve"],
                  "knees": knees, "claims": claims}


if __name__ == "__main__":
    run()
