"""Fig 6 reproduction: response time at varying offered load (open-loop
Poisson), containerd vs junctiond.

Paper claims: junctiond sustains ~10x more throughput while lowering
median latency ~2x and the tail ~3.5x.
"""
from __future__ import annotations

from repro.core import FaasdRuntime, FunctionSpec, Simulator, run_open_loop

RATES_BASE = [500, 1000, 1250, 1500, 1750]
RATES_JUNC = [2000, 5000, 9000, 12000, 13000, 14000]
SLO_P99_MS = 10.0


def _sweep(backend, rates, duration_s=1.5, seed=3):
    curve = []
    for rate in rates:
        sim = Simulator(seed=seed)
        rt = FaasdRuntime(sim, backend=backend)
        rt.deploy_blocking(FunctionSpec(name="aes", max_cores=8))
        res = run_open_loop(rt, "aes", rate_rps=rate, duration_s=duration_s)
        curve.append(res)
    return curve


def _knee(curve):
    best = 0.0
    for r in curve:
        if (r["p99_ms"] <= SLO_P99_MS and r["rejected"] == 0
                and r["achieved_rps"] >= 0.85 * r["offered_rps"]):
            best = max(best, r["offered_rps"])
    return best


def run(verbose=True, duration_s=1.5):
    c_curve = _sweep("containerd", RATES_BASE, duration_s)
    j_curve = _sweep("junctiond", RATES_JUNC, duration_s)
    c_knee, j_knee = _knee(c_curve), _knee(j_curve)
    ratio = j_knee / max(1.0, c_knee)
    # latency comparison at the baseline's knee load
    c_at = next(r for r in c_curve if r["offered_rps"] == c_knee)
    j_at = min(j_curve, key=lambda r: abs(r["offered_rps"] - c_knee * 1.3))
    med_x = c_at["median_ms"] / j_at["median_ms"]
    p99_x = c_at["p99_ms"] / j_at["p99_ms"]
    if verbose:
        print("# fig6: open-loop load sweep (p99 SLO %.0fms)" % SLO_P99_MS)
        for name, curve in (("containerd", c_curve), ("junctiond", j_curve)):
            print(f"  {name}:")
            for r in curve:
                print(f"    rate={r['offered_rps']:6.0f} achieved={r['achieved_rps']:8.0f} "
                      f"median={r['median_ms']:8.2f}ms p99={r['p99_ms']:9.2f}ms")
        print(f"  sustainable: containerd={c_knee:.0f} rps, junctiond={j_knee:.0f} rps "
              f"-> {ratio:.1f}x (paper: ~10x)")
        print(f"  at-load latency: median {med_x:.2f}x lower (paper ~2x), "
              f"p99 {p99_x:.2f}x lower (paper ~3.5x)")
    rows = [
        ("fig6_containerd_sustainable_rps", c_knee, "rps at p99<=10ms"),
        ("fig6_junctiond_sustainable_rps", j_knee, "rps at p99<=10ms"),
        ("fig6_throughput_ratio", ratio, "x (paper ~10x)"),
        ("fig6_median_speedup_at_load", med_x, "x (paper ~2x)"),
        ("fig6_p99_speedup_at_load", p99_x, "x (paper ~3.5x)"),
    ]
    return rows, {"containerd": c_curve, "junctiond": j_curve,
                  "knees": {"containerd": c_knee, "junctiond": j_knee}}


if __name__ == "__main__":
    run()
