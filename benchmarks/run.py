"""Benchmark harness: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV at the end, as well as each
bench's human-readable report.
"""
from __future__ import annotations

import sys
import time

from benchmarks import (aes_function, coldstart, fig5_latency, fig6_load,
                        model_endpoints, multitenant, polling_efficiency,
                        roofline_table)

BENCHES = [
    ("fig5_latency", fig5_latency),
    ("fig6_load", fig6_load),
    ("coldstart", coldstart),
    ("polling_efficiency", polling_efficiency),
    ("multitenant", multitenant),
    ("aes_function", aes_function),
    ("model_endpoints", model_endpoints),
    ("roofline_table", roofline_table),
]


def main() -> None:
    all_rows = []
    for name, mod in BENCHES:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            rows, _ = mod.run(verbose=True)
            all_rows.extend(rows)
        except Exception as e:
            print(f"  BENCH FAILED: {e!r}")
            all_rows.append((f"{name}_FAILED", float("nan"), repr(e)))
        print(f"  [{time.time() - t0:.1f}s]")
    print("\nname,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
