"""Benchmark harness.

Two families of suites:

* scenario suites (``--suite scenarios|smoke|paper``) — declarative
  Scenario specs executed by :class:`repro.experiments.ExperimentRunner`
  across each scenario's backend matrix (default: the paper's
  containerd/junctiond pair; ``--backends`` widens it to any registered
  set), emitting a machine-readable ``BENCH_<suite>.json`` artifact
  (``--json``) with per-scenario latency histograms, knee/SLO metrics,
  and paper-claim deltas computed from the claims pair.  Open-mode
  scenarios locate their SLO knee with the adaptive search by default
  (``--search-budget`` caps its per-backend probe count); scenarios that
  pin explicit rate grids sweep them unchanged.
* ``--suite legacy`` (default) — the original one-module-per-figure
  benches, printing ``name,value,derived`` CSV.
* ``--list`` — enumerate registered backends and scenarios (names, modes,
  rate grids; fleet scenarios additionally show their simulated worker
  count, placement policies and image-distribution strategies) without
  running anything.

Exit status is nonzero when any bench or scenario cell fails.

Examples::

    python -m benchmarks.run --suite smoke --json BENCH_ci.json
    python -m benchmarks.run --suite smoke \
        --backends containerd,junctiond,quark,wasm,firecracker,gvisor \
        --json BENCH_ci.json
    python -m benchmarks.run --suite scenarios --json BENCH_scenarios.json \
        --workers 4
    python -m benchmarks.run --list
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

from repro.core.backends import available_backends, get_backend_class
from repro.experiments import (SMOKE_DURATION_SCALE, SUITES,
                               ExperimentRunner, build_artifact, build_scenarios,
                               get_suite, metric_row, metrics_csv,
                               write_artifact)

def _legacy_benches():
    # imported lazily: aes_function pulls in jax, which --list and the
    # scenario suites never need
    from benchmarks import (aes_function, coldstart, fig5_latency, fig6_load,
                            model_endpoints, multitenant, polling_efficiency,
                            roofline_table)
    return [
        ("fig5_latency", fig5_latency),
        ("fig6_load", fig6_load),
        ("coldstart", coldstart),
        ("polling_efficiency", polling_efficiency),
        ("multitenant", multitenant),
        ("aes_function", aes_function),
        ("model_endpoints", model_endpoints),
        ("roofline_table", roofline_table),
    ]


def run_legacy(args) -> int:
    all_rows, failures = [], []
    for name, mod in _legacy_benches():
        print(f"\n===== {name} =====")
        # simlint: allow[wall-clock] prints host elapsed per legacy bench
        t0 = time.time()
        try:
            rows, _ = mod.run(verbose=True)
            all_rows.extend(rows)
        except Exception as e:
            print(f"  BENCH FAILED: {e!r}")
            all_rows.append((f"{name}_FAILED", float("nan"), repr(e)))
            failures.append({"scenario": name, "backend": "-",
                             "error": repr(e)})
        # simlint: allow[wall-clock] prints host elapsed per legacy bench
        print(f"  [{time.time() - t0:.1f}s]")
    print("\nname,value,derived")
    for name, value, derived in all_rows:
        v = float(value) if isinstance(value, (int, float)) else float("nan")
        print(f"{name},{v:.3f},{derived}")
    metrics = [metric_row(n, v, d) for n, v, d in all_rows]
    if args.sim_throughput:
        print()
        run_sim_throughput({"metrics": metrics})
    if args.json:
        write_artifact(args.json, build_artifact("legacy", [], metrics,
                                                 failures))
        print(f"\nwrote {args.json}")
    if failures:
        print(f"\n{len(failures)} bench(es) FAILED", file=sys.stderr)
    return 1 if failures else 0


def measure_sim_throughput(duration_s: float = 8.0, rate_rps: float = 1200.0,
                           backend: str = "containerd", seed: int = 0,
                           repeats: int = 3):
    """Simulated-requests-per-wall-second of both ``drive`` engines on a
    reference workload (containerd just under its SLO knee — deep
    queueing, the regime the pre-PR generator driver spent its wall time
    in).

    Each engine runs several times on a fresh same-seed runtime and
    keeps the *minimum* wall: the simulation itself is deterministic, so
    run-to-run spread is pure machine noise, and that noise is one-sided
    (contention only ever adds time).  min-wall is the stable estimator
    a hard CI gate can sit on.  The events engine is ~25x cheaper per
    run, so it gets ``2 * repeats + 1`` attempts to land in a quiet
    scheduling window for the price of a fraction of one process run.

    Returns ``{"events": {...}, "process": {...}, "speedup": float}``
    where each engine entry carries ``n`` (admitted requests), ``wall_s``
    and ``sim_rps``.  The events/process ratio is the raw-speed gate CI
    asserts on (>= 20x)."""
    from repro.core import (FaasdRuntime, FunctionSpec, LoadSpec, Simulator,
                            drive)
    out = {}
    for engine in ("events", "process"):
        wall, n = float("inf"), 0
        tries = 2 * repeats + 1 if engine == "events" else repeats
        for _ in range(max(1, tries)):
            sim = Simulator(seed=seed)
            rt = FaasdRuntime(sim, backend=backend)
            rt.deploy_blocking(FunctionSpec(name="aes"))
            load = LoadSpec.single("aes", rate_rps, duration_s=duration_s)
            # simlint: allow[wall-clock] benchmarks the simulator itself
            t0 = time.perf_counter()
            res = drive(rt, load, engine=engine)
            # simlint: allow[wall-clock] benchmarks the simulator itself
            wall = min(wall, max(time.perf_counter() - t0, 1e-9))
            n = res["n"]
        out[engine] = {"n": n, "wall_s": wall, "sim_rps": n / wall}
    out["speedup"] = out["events"]["sim_rps"] / out["process"]["sim_rps"]
    return out


def measure_fleet_sim_throughput(duration_s: float = 4.0,
                                 rate_rps: float = 12000.0,
                                 n_workers: int = 32,
                                 backend: str = "containerd", seed: int = 0,
                                 repeats: int = 3):
    """Simulated-requests-per-wall-second of ``drive`` over the fleet
    reference: a 32-worker containerd cluster behind one gateway, offered
    an aggregate open-loop rate sized to the single-runtime reference
    (1200 rps x ~10 workers' worth of headroom), least-loaded placement.

    Same min-wall estimator as :func:`measure_sim_throughput`.  Returns
    ``{"n", "wall_s", "sim_rps", "per_worker_rps"}`` where
    ``per_worker_rps`` normalises by the fleet size — the
    machine-portable sanity figure (routing + per-worker pools cost a
    bounded factor over the single-runtime driver, not a per-worker
    slowdown)."""
    from repro.core import FunctionSpec, LoadSpec, Simulator, drive
    from repro.fleet import Cluster
    wall, n = float("inf"), 0
    for _ in range(max(1, 2 * repeats + 1)):
        sim = Simulator(seed=seed)
        cl = Cluster(sim, n_workers, backend=backend)
        cl.deploy_blocking(FunctionSpec(name="aes"))
        load = LoadSpec.single("aes", rate_rps, duration_s=duration_s)
        # simlint: allow[wall-clock] benchmarks the simulator itself
        t0 = time.perf_counter()
        res = drive(cl, load)
        # simlint: allow[wall-clock] benchmarks the simulator itself
        wall = min(wall, max(time.perf_counter() - t0, 1e-9))
        n = res["n"]
    return {"n": n, "wall_s": wall, "sim_rps": n / wall,
            "per_worker_rps": n / wall / n_workers}


def run_sim_throughput(doc=None) -> dict:
    """Measure, print the stable one-line summaries CI greps, and (when
    an artifact dict is given) append the metric rows."""
    m = measure_sim_throughput()
    ev, pr = m["events"], m["process"]
    print(f"sim_throughput: events={ev['sim_rps']:.0f} req/s "
          f"process={pr['sim_rps']:.0f} req/s speedup={m['speedup']:.1f}x "
          f"(n={ev['n']}, containerd@1200rps)")
    fl = measure_fleet_sim_throughput()
    m["fleet"] = fl
    print(f"fleet_sim_throughput: events={fl['sim_rps']:.0f} req/s "
          f"({fl['n']} requests, 32 workers, containerd@12000rps "
          f"aggregate)")
    if doc is not None:
        doc["metrics"].append(metric_row(
            "sim_throughput", ev["sim_rps"],
            f"{ev['n']} simulated requests / {ev['wall_s']:.3f}s wall "
            f"(events engine, containerd@1200rps)"))
        doc["metrics"].append(metric_row(
            "sim_throughput_speedup", m["speedup"],
            f"events {ev['sim_rps']:.0f} req/s vs process "
            f"{pr['sim_rps']:.0f} req/s on the reference workload"))
        doc["metrics"].append(metric_row(
            "fleet_sim_throughput", fl["sim_rps"],
            f"{fl['n']} simulated requests / {fl['wall_s']:.3f}s wall "
            f"(32-worker containerd cluster @ 12000rps aggregate)"))
    return m


def run_profile(args) -> int:
    """Run one (scenario, backend) cell under cProfile and print the
    top-25 cumulative entries — the starting point for perf work."""
    import cProfile
    import pstats
    spec = args.profile
    scenario_name, _, backend = spec.partition(":")
    scenarios = {sc.name: sc for sc in build_scenarios().values()}
    if scenario_name not in scenarios:
        raise SystemExit(f"unknown scenario {scenario_name!r}; "
                         f"see --list for names")
    sc = scenarios[scenario_name]
    backend = backend or sc.backends[0]
    if backend not in sc.backends:
        sc = dataclasses.replace(sc, backends=(backend,))
    smoke = args.suite == "smoke"
    scale = args.duration * (SMOKE_DURATION_SCALE if smoke else 1.0)
    runner = ExperimentRunner(duration_scale=scale, smoke=smoke,
                              verbose=False)
    print(f"profiling {scenario_name}/{backend} "
          f"(duration_scale={scale:.2f})")
    prof = cProfile.Profile()
    prof.enable()
    runner.run_suite([dataclasses.replace(sc, backends=(backend,))],
                     suite="profile")
    prof.disable()
    pstats.Stats(prof).sort_stats("cumulative").print_stats(25)
    return 0


def _parse_backends(spec: str):
    names = list(dict.fromkeys(      # dedupe, keeping the given order
        b.strip() for b in spec.split(",") if b.strip()))
    registered = available_backends()
    unknown = [b for b in names if b not in registered]
    if unknown:
        raise SystemExit(f"unknown backend(s) {', '.join(unknown)}; "
                         f"registered: {', '.join(registered)}")
    return tuple(names)


def run_list(args) -> int:
    """Enumerate registered backends and scenarios without running."""
    print("registered backends:")
    for name in available_backends():
        cls = get_backend_class(name)
        cs = cls.coldstart
        print(f"  {name:11s} runtime={cls.runtime.name:8s} "
              f"stack={cls.stack_costs.name:9s} "
              f"coldstart={cs.deploy_ms:g}ms query={cs.query_ms:g}ms")
    print("\nscenarios:")
    for name, sc in sorted(build_scenarios().items()):
        asc = sc.autoscaler.policy if sc.autoscaler else "-"
        search = sc.search_spec()
        load = "search" if search is not None else \
            "grid" if sc.mode in ("open", "mixed", "fleet") and sc.rates \
            else "-"
        print(f"  {name:17s} mode={sc.mode:6s} arrival={sc.arrival.kind:8s} "
              f"load={load:6s} backends={','.join(sc.backends)} "
              f"claims={sc.claims_kind or '-'} autoscaler={asc}")
        if search is not None:
            print(f"    search: rel_tol={search.rel_tol:g} "
                  f"max_probes={search.max_probes} "
                  f"(smoke {search.smoke_rel_tol:g}/"
                  f"{search.smoke_max_probes}) "
                  f"growth={search.growth:g} "
                  f"rate0={'auto' if search.rate0 is None else search.rate0}")
        elif sc.mode in ("open", "mixed", "fleet") and sc.rates:
            unit = " rps/worker" if sc.mode == "fleet" else ""
            for b, grid in sorted(sc.rates.items()):
                print(f"    rates[{b}] = "
                      f"{', '.join(f'{r:g}' for r in grid)}{unit}")
        if sc.fleet is not None:
            fl = sc.fleet
            storm = (f" storm={fl.storm_replicas}r@"
                     f"{fl.storm_t_frac:g}T" if fl.storm_replicas else "")
            print(f"    fleet: workers={fl.n_workers} "
                  f"placement={'/'.join(fl.placements())} "
                  f"distribution={'/'.join(fl.distributions())} "
                  f"spread={fl.spread} image={fl.image_mb:g}MB{storm}")
    print("\nsuites:")
    for suite, names in sorted(SUITES.items()):
        print(f"  {suite:10s} = {', '.join(names)}")
    return 0


def run_scenarios(args) -> int:
    smoke = args.suite == "smoke"
    scale = args.duration * (SMOKE_DURATION_SCALE if smoke else 1.0)
    runner = ExperimentRunner(duration_scale=scale, smoke=smoke,
                              workers=args.workers, verbose=True)
    scenarios = get_suite(args.suite)
    if args.backends:
        matrix = _parse_backends(args.backends)
        scenarios = [dataclasses.replace(sc, backends=matrix)
                     for sc in scenarios]
    if args.search_budget is not None:
        if args.search_budget < 1:
            raise SystemExit("--search-budget must be >= 1")
        # cap the per-(backend, seed) open-loop sample budget of every
        # searched scenario; grid/mixed/closed scenarios are unaffected
        def _capped(sc):
            spec = sc.search_spec()
            if spec is None:
                return sc
            return dataclasses.replace(sc, search=dataclasses.replace(
                spec, max_probes=args.search_budget,
                smoke_max_probes=args.search_budget))
        scenarios = [_capped(sc) for sc in scenarios]
    backend_union = sorted({b for sc in scenarios for b in sc.backends})
    print(f"suite={args.suite}: {len(scenarios)} scenarios x "
          f"{{{', '.join(backend_union)}}}, duration_scale={scale:.2f}, "
          f"workers={args.workers}")
    doc = runner.run_suite(scenarios, suite=args.suite)
    for entry in doc["scenarios"]:
        print(f"\n===== {entry['name']} ({entry['mode']}, "
              f"{entry['arrival_kind']} arrivals) =====")
        for backend, res in entry["backends"].items():
            bits = [f"n={res.get('n', 0)}"]
            if res.get("knee_rps") is not None and entry["mode"] == "open":
                bits.append(f"knee={res['knee_rps']:.0f}rps")
            if "search" in res:
                s = res["search"]
                # non-convergence has two distinct causes: the probe
                # budget ran out, or no failing bound was found within it
                # (knee is only a lower bound / nothing was sustainable)
                tag = "" if s["converged"] else (
                    " (budget)" if any(t["n_probes"] >=
                                       s["spec"]["max_probes"]
                                       for t in s["trace"])
                    else " (unbounded)")
                bits.append(f"probes={s['n_probes']}{tag}")
            if isinstance(res.get("median_ms"), float):
                bits.append(f"median={res['median_ms']:.3f}ms")
                bits.append(f"p99={res['p99_ms']:.3f}ms")
            if "autoscaler" in res:
                a = res["autoscaler"]
                bits.append(f"scale_events={a['n_scale_events']} "
                            f"reaction_p50={a['reaction_p50_ms']:.1f}ms")
            if "fleet" in res:
                fl = res["fleet"]
                bits.append(f"workers={fl['n_workers']}x{fl['placement']}")
                spd = fl.get("tree_provisioning_speedup")
                if spd is not None:
                    bits.append(f"tree_speedup={spd:g}x")
            bits.append(f"[{res.get('elapsed_s', 0):.1f}s]")
            print(f"  {backend:11s} " + " ".join(bits))
        for key, cl in entry.get("claims", {}).items():
            paper = f" (paper {cl['paper']})" if "paper" in cl else ""
            print(f"    claim {key:28s} = {cl['measured']}{paper}")
    if args.sim_throughput:
        print()
        run_sim_throughput(doc)
    print()
    print(metrics_csv(doc))
    if args.json:
        write_artifact(args.json, doc)
        print(f"\nwrote {args.json} "
              f"({doc['meta']['wall_s']:.1f}s wall)")
    if doc["failures"]:
        for f in doc["failures"]:
            print(f"\nFAILED {f['scenario']}/{f['backend']}:\n{f['error']}",
                  file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--suite", default="legacy",
                    choices=["legacy"] + sorted(SUITES),
                    help="which suite to run (default: legacy)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable bench artifact here")
    ap.add_argument("--duration", type=float, default=1.0, metavar="SCALE",
                    help="duration scale factor on top of the suite default "
                         "(smoke already applies %.2fx)" % SMOKE_DURATION_SCALE)
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="parallel OS processes the harness farms scenario "
                         "cells out to (0 = in-process, deterministic "
                         "ordering); unrelated to a fleet scenario's "
                         "simulated worker count, which is fixed by its "
                         "FleetSpec.n_workers (see --list)")
    ap.add_argument("--backends", metavar="A,B,...", default=None,
                    help="comma-separated registered backend names to run "
                         "every scenario against (default: each scenario's "
                         "own matrix, normally containerd,junctiond)")
    ap.add_argument("--search-budget", type=int, default=None, metavar="N",
                    help="cap the adaptive knee search at N open-loop "
                         "probes per (backend, seed); applies to every "
                         "search-mode scenario (grid scenarios unaffected)")
    ap.add_argument("--profile", metavar="SCENARIO[:BACKEND]", default=None,
                    help="run one (scenario, backend) cell under cProfile "
                         "and print the top-25 cumulative entries, then "
                         "exit (default backend: the scenario's first)")
    ap.add_argument("--sim-throughput", action="store_true",
                    help="also measure simulated-requests-per-wall-second "
                         "of both drive() engines on the reference workload "
                         "and record sim_throughput / "
                         "sim_throughput_speedup in the artifact")
    ap.add_argument("--list", action="store_true",
                    help="list registered backends, scenarios and suites, "
                         "then exit")
    args = ap.parse_args(argv)
    if args.list:
        return run_list(args)
    if args.profile:
        return run_profile(args)
    if args.suite == "legacy":
        # simlint: allow[float-eq] argparse default sentinel, no arithmetic
        if args.duration != 1.0 or args.workers or args.backends \
                or args.search_budget is not None:
            print("note: --duration/--workers/--backends/--search-budget "
                  "only apply to scenario suites; the legacy suite ignores "
                  "them", file=sys.stderr)
        return run_legacy(args)
    return run_scenarios(args)


if __name__ == "__main__":
    sys.exit(main())
