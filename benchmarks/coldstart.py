"""Cold-start reproduction (paper §5): Junction instance init 3.4 ms vs
containerd container start; plus junctiond scale-up paths (uProc spawn vs
isolated sibling instance)."""
from __future__ import annotations

from repro.core import FaasdRuntime, FunctionSpec, Simulator


def _deploy_time(backend, **kw) -> float:
    sim = Simulator()
    rt = FaasdRuntime(sim, backend=backend)
    t0 = sim.now
    rt.deploy_blocking(FunctionSpec(name="f", **kw))
    return (sim.now - t0) * 1e3


def run(verbose=True):
    j = _deploy_time("junctiond")
    c = _deploy_time("containerd")
    # scale 4 replicas inside ONE instance (uProcs) vs 4 isolated instances
    sim = Simulator()
    rt = FaasdRuntime(sim, backend="junctiond")
    t0 = sim.now
    p = sim.process(rt.manager.deploy("f4", scale=4, isolate_replicas=False))
    p.completion.callbacks.append(lambda _v: sim.stop())
    sim.run()
    shared = (sim.now - t0) * 1e3
    sim2 = Simulator()
    rt2 = FaasdRuntime(sim2, backend="junctiond")
    t0 = sim2.now
    p = sim2.process(rt2.manager.deploy("f4i", scale=4, isolate_replicas=True))
    p.completion.callbacks.append(lambda _v: sim2.stop())
    sim2.run()
    isolated = (sim2.now - t0) * 1e3
    if verbose:
        print("# cold start")
        print(f"  junction instance init : {j:8.2f} ms  (paper: 3.4 ms)")
        print(f"  containerd cold start  : {c:8.2f} ms")
        print(f"  junctiond scale=4 uProcs (shared instance)  : {shared:8.2f} ms")
        print(f"  junctiond scale=4 isolated instances        : {isolated:8.2f} ms")
    rows = [("coldstart_junction_init", j * 1e3, "us (paper 3.4ms)"),
            ("coldstart_containerd", c * 1e3, "us"),
            ("coldstart_ratio", c / j, "x containerd/junction"),
            ("scaleup_shared_uprocs_4", shared * 1e3, "us"),
            ("scaleup_isolated_4", isolated * 1e3, "us")]
    return rows, {"junction_ms": j, "containerd_ms": c}


if __name__ == "__main__":
    run()
