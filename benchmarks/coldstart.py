"""Cold-start reproduction (paper §5): Junction instance init 3.4 ms vs
containerd container start, measured under a concurrent deploy storm
(FaaSNet's bursty provisioning regime) by the ``cold-start-storm``
scenario; plus junctiond scale-up paths (uProc spawn vs isolated sibling
instance), which stay a direct manager measurement."""
from __future__ import annotations

from repro.core import FaasdRuntime, Simulator
from repro.experiments import ExperimentRunner, get_scenario


def _scale_up_ms(isolate: bool) -> float:
    sim = Simulator()
    rt = FaasdRuntime(sim, backend="junctiond")
    t0 = sim.now
    p = sim.process(rt.manager.deploy("f4", scale=4,
                                      isolate_replicas=isolate))
    p.completion.callbacks.append(lambda _v: sim.stop())
    sim.run()
    return (sim.now - t0) * 1e3


def run(verbose=True):
    doc = ExperimentRunner().run_suite([get_scenario("cold-start-storm")],
                                       suite="coldstart")
    if doc["failures"]:
        raise RuntimeError(doc["failures"][0]["error"])
    entry = doc["scenarios"][0]
    claims = entry["claims"]
    j = claims["treatment_init_ms"]["measured"]
    c = claims["baseline_coldstart_ms"]["measured"]
    shared = _scale_up_ms(isolate=False)
    isolated = _scale_up_ms(isolate=True)
    if verbose:
        storm_j = entry["backends"]["junctiond"]
        print("# cold start")
        print(f"  junction instance init : {j:8.2f} ms  (paper: 3.4 ms)")
        print(f"  containerd cold start  : {c:8.2f} ms")
        print(f"  storm ({storm_j['functions']} concurrent deploy+invoke): "
              f"junctiond median {storm_j['median_ms']:.2f} ms, "
              f"{claims['storm_speedup']['measured']:.0f}x faster than "
              "containerd")
        print(f"  junctiond scale=4 uProcs (shared instance)  : {shared:8.2f} ms")
        print(f"  junctiond scale=4 isolated instances        : {isolated:8.2f} ms")
    rows = [(m["name"], m["value"], m["derived"]) for m in doc["metrics"]
            if m["name"].startswith("coldstart_")]
    rows += [("scaleup_shared_uprocs_4", shared * 1e3, "us"),
             ("scaleup_isolated_4", isolated * 1e3, "us")]
    return rows, {"junction_ms": j, "containerd_ms": c, "claims": claims}


if __name__ == "__main__":
    run()
