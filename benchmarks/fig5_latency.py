"""Fig 5 reproduction: latency distribution of 100 sequential AES(600 B)
invocations, containerd vs junctiond, observed from the gateway.

Paper claims: median -37.33%, P99 -63.42% end-to-end; function execution
median -35.3%, P99 -81%.

Thin adapter over the ``paper-fig5`` scenario in
:mod:`repro.experiments.suites`; the measurement itself lives in the
experiment runner.
"""
from __future__ import annotations

import dataclasses

from repro.experiments import ExperimentRunner, get_scenario

PAPER = {"e2e_median": 37.33, "e2e_p99": 63.42, "exec_median": 35.3,
         "exec_p99": 81.0}


def run(seeds=range(8), n=100, verbose=True):
    sc = dataclasses.replace(get_scenario("paper-fig5"),
                             seeds=tuple(seeds), n_requests=n)
    doc = ExperimentRunner().run_suite([sc], suite="fig5")
    if doc["failures"]:
        raise RuntimeError(doc["failures"][0]["error"])
    entry = doc["scenarios"][0]
    c = entry["backends"]["containerd"]
    j = entry["backends"]["junctiond"]
    claims = entry["claims"]
    if verbose:
        print(f"# fig5: {n} sequential AES(600B) invocations "
              f"({len(sc.seeds)} seeds)")
        print(f"  containerd: median={c['median_ms']:.3f}ms p99={c['p99_ms']:.3f}ms "
              f"exec median={c['exec_median_ms']:.3f} p99={c['exec_p99_ms']:.3f}")
        print(f"  junctiond : median={j['median_ms']:.3f}ms p99={j['p99_ms']:.3f}ms "
              f"exec median={j['exec_median_ms']:.3f} p99={j['exec_p99_ms']:.3f}")
        for k, cl in claims.items():
            print(f"  reduction {k:28s}: {cl['measured']:6.2f}%   "
                  f"(paper: {cl['paper']}%)")
    rows = [(m["name"], m["value"], m["derived"]) for m in doc["metrics"]
            if m["name"].startswith("fig5_")]
    return rows, {"measured": {"containerd": c, "junctiond": j},
                  "claims": claims, "paper": PAPER}


if __name__ == "__main__":
    run()
