"""Fig 5 reproduction: latency distribution of 100 sequential AES(600 B)
invocations, containerd vs junctiond, observed from the gateway.

Paper claims: median -37.33%, P99 -63.42% end-to-end; function execution
median -35.3%, P99 -81%.
"""
from __future__ import annotations

import numpy as np

from repro.core import (FaasdRuntime, FunctionSpec, LatencySummary,
                        Simulator, run_sequential)

PAPER = {"e2e_median": 37.33, "e2e_p99": 63.42, "exec_median": 35.3,
         "exec_p99": 81.0}


def run(seeds=range(8), n=100, verbose=True):
    res = {}
    for backend in ("containerd", "junctiond"):
        e2e, exe = [], []
        for seed in seeds:
            sim = Simulator(seed=seed)
            rt = FaasdRuntime(sim, backend=backend)
            rt.deploy_blocking(FunctionSpec(name="aes"))
            e2e.append(run_sequential(rt, "aes", n=n))
            exe.append(LatencySummary.of(rt.exec_latencies_ms()))
        res[backend] = {
            "median_ms": float(np.mean([s.median_ms for s in e2e])),
            "p99_ms": float(np.mean([s.p99_ms for s in e2e])),
            "exec_median_ms": float(np.mean([s.median_ms for s in exe])),
            "exec_p99_ms": float(np.mean([s.p99_ms for s in exe])),
        }
    c, j = res["containerd"], res["junctiond"]
    out = {
        "e2e_median": 100 * (1 - j["median_ms"] / c["median_ms"]),
        "e2e_p99": 100 * (1 - j["p99_ms"] / c["p99_ms"]),
        "exec_median": 100 * (1 - j["exec_median_ms"] / c["exec_median_ms"]),
        "exec_p99": 100 * (1 - j["exec_p99_ms"] / c["exec_p99_ms"]),
    }
    if verbose:
        print("# fig5: 100 sequential AES(600B) invocations (8 seeds)")
        print(f"  containerd: median={c['median_ms']:.3f}ms p99={c['p99_ms']:.3f}ms "
              f"exec median={c['exec_median_ms']:.3f} p99={c['exec_p99_ms']:.3f}")
        print(f"  junctiond : median={j['median_ms']:.3f}ms p99={j['p99_ms']:.3f}ms "
              f"exec median={j['exec_median_ms']:.3f} p99={j['exec_p99_ms']:.3f}")
        for k, v in out.items():
            print(f"  reduction {k:12s}: {v:6.2f}%   (paper: {PAPER[k]}%)")
    rows = [("fig5_containerd_median", c["median_ms"] * 1e3, "us e2e"),
            ("fig5_junctiond_median", j["median_ms"] * 1e3, "us e2e"),
            ("fig5_median_reduction", out["e2e_median"], f"% vs paper {PAPER['e2e_median']}%"),
            ("fig5_p99_reduction", out["e2e_p99"], f"% vs paper {PAPER['e2e_p99']}%"),
            ("fig5_exec_median_reduction", out["exec_median"], f"% vs paper {PAPER['exec_median']}%"),
            ("fig5_exec_p99_reduction", out["exec_p99"], f"% vs paper {PAPER['exec_p99']}%")]
    return rows, {"measured": res, "reductions": out, "paper": PAPER}


if __name__ == "__main__":
    run()
