"""The 40-pair roofline table from the dry-run records (§Roofline)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(mesh: str = "pod16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(verbose=True):
    recs = load_records()
    rows = []
    if verbose:
        print("# roofline table (single-pod 16x16 = 256 chips, v5e terms)")
        print(f"  {'arch':25s} {'shape':12s} {'compute_ms':>10s} {'memory_ms':>10s} "
              f"{'coll_ms':>9s} {'bound':>10s} {'useful':>7s} {'mem_GB':>7s}")
    for rec in recs:
        roof = rec.get("roofline")
        if not roof:
            continue
        if verbose:
            print(f"  {rec['arch']:25s} {rec['shape']:12s} "
                  f"{roof['compute_s']*1e3:10.2f} {roof['memory_s']*1e3:10.2f} "
                  f"{roof['collective_s']*1e3:9.2f} {roof['bottleneck']:>10s} "
                  f"{roof['useful_ratio']:7.3f} "
                  f"{rec['memory'].get('total_gb', float('nan')):7.2f}")
        rows.append((f"roofline_{rec['arch']}_{rec['shape']}",
                     roof["step_time_s"] * 1e6,
                     f"us/step {roof['bottleneck']}-bound useful={roof['useful_ratio']:.2f}"))
    if verbose:
        n_multi = len(load_records("pod2x16x16"))
        print(f"  multi-pod (2x16x16) compiled pairs: {n_multi}")
    return rows, {}


if __name__ == "__main__":
    run()
