"""Paper §3 resource-efficiency claim: "Junction can use a single dedicated
core to manage thousands of functions", vs one polling core per instance
for naive kernel-bypass (DPDK-style)."""
from __future__ import annotations

from repro.core import JunctionInstance, PollingModel, Simulator
from repro.core.latency import JUNCTION_RUNTIME
from repro.core.resources import CorePool
from repro.core.scheduler import JunctionScheduler


def _cores_left(model: PollingModel, n_functions: int, n_cores: int = 36) -> int:
    sim = Simulator()
    pool = CorePool(sim, n_cores, JUNCTION_RUNTIME)
    sched = JunctionScheduler(sim, pool, model)
    for i in range(n_functions):
        inst = JunctionInstance(sim, f"f{i}")
        inst.ready = True
        sched.register(inst)
        if pool.n_cores <= 0:
            break
    return pool.n_cores


def _poll_cost(n_functions: int) -> float:
    sim = Simulator()
    pool = CorePool(sim, 36, JUNCTION_RUNTIME)
    sched = JunctionScheduler(sim, pool)
    for i in range(n_functions):
        inst = JunctionInstance(sim, f"f{i}")
        inst.ready = True
        sched.register(inst)
    sched.run()
    sim.run(until=0.05)
    return sched.polling_cost_per_iteration()


def run(verbose=True):
    rows = []
    if verbose:
        print("# polling efficiency on a 36-core server (paper §3)")
        print("  functions | centralized cores-for-work | per-instance cores-for-work")
    for n in (1, 8, 32, 100, 1000):
        cen = _cores_left(PollingModel.CENTRALIZED, n)
        per = _cores_left(PollingModel.PER_INSTANCE, n)
        if verbose:
            print(f"  {n:9d} | {cen:26d} | {per:28d}")
        rows.append((f"polling_cores_left_centralized_{n}", cen, "of 36"))
        rows.append((f"polling_cores_left_per_instance_{n}", per, "of 36"))
    c10, c1000 = _poll_cost(10), _poll_cost(1000)
    if verbose:
        print(f"  scheduler decision work/iter: 10 fns={c10:.2f}  1000 fns={c1000:.2f} "
              "(∝ cores, NOT instances)")
    rows.append(("polling_decision_work_10fns", c10, "units/iter"))
    rows.append(("polling_decision_work_1000fns", c1000, "units/iter"))
    return rows, {}


if __name__ == "__main__":
    run()
