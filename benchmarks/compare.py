"""Diff two ``BENCH_<suite>.json`` artifacts and flag metric regressions.

The flat ``metrics`` table is the stable cross-run surface (the runner
keeps names pair-derived, so a containerd/junctiond run compares against
any older artifact).  Each metric is classified by name into
higher-is-better (ratios, speedups, reductions, sustainable rps) or
lower-is-better (latencies), and a relative change beyond
``--threshold`` in the bad direction is a regression.  Metrics present in
the old artifact but missing from the new one are regressions too (a
silently dropped gate is the failure mode this tool exists for).

Exit status: 0 when clean, 1 when any regression was found — so CI can
gate on ``python -m benchmarks.compare OLD.json NEW.json``.

Examples::

    python -m benchmarks.compare BENCH_main.json BENCH_ci.json
    python -m benchmarks.compare old.json new.json --threshold 0.05 --all
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Tuple

from repro.experiments import validate_artifact

# name fragments marking metrics where larger values are better; anything
# else (latency medians/p99s, init times) regresses when it grows.
# "sim_throughput" is covered by the "throughput" fragment but listed
# explicitly: it is the raw-speed gate of the event-heap driver and must
# never silently flip direction if the fragment list is pruned.
_HIGHER_IS_BETTER = ("ratio", "speedup", "reduction", "sustainable",
                     "knee", "throughput", "sim_throughput", "_rps",
                     "improvement", "efficiency")

THRESHOLD_DEFAULT = 0.10


def _direction(name: str) -> str:
    lname = name.lower()
    if any(tok in lname for tok in _HIGHER_IS_BETTER):
        return "higher"
    return "lower"


def _load(path: str) -> Dict[str, object]:
    with open(path) as f:
        doc = json.load(f)
    # v1 artifacts (older commits) validate too — the flat metrics table,
    # the only surface this tool reads, has been stable since v1
    validate_artifact(doc)
    return doc


def compare_metrics(old: Dict[str, object], new: Dict[str, object],
                    threshold: float = THRESHOLD_DEFAULT,
                    ) -> Tuple[List[dict], List[str]]:
    """Row per old metric: name, old/new values, relative delta, status in
    {ok, improved, regressed, missing, nan}; plus the list of new-only
    metric names (informational)."""
    old_m = {m["name"]: m["value"] for m in old["metrics"]}
    new_m = {m["name"]: m["value"] for m in new["metrics"]}
    rows: List[dict] = []
    for name, ov in old_m.items():
        direction = _direction(name)
        row = {"name": name, "old": ov, "new": new_m.get(name),
               "direction": direction, "rel_delta": None}
        if name not in new_m:
            row["status"] = "missing"
        elif ov is None or new_m[name] is None:
            # None encodes NaN in the artifact; losing a number is a
            # regression, (re)gaining one is not
            row["status"] = "nan" if new_m[name] is None and ov is not None \
                else "ok"
        else:
            nv = new_m[name]
            if ov == 0:
                rel = 0.0 if nv == 0 else math.copysign(math.inf, nv)
            else:
                rel = (nv - ov) / abs(ov)
            row["rel_delta"] = rel
            worse = rel < -threshold if direction == "higher" \
                else rel > threshold
            better = rel > threshold if direction == "higher" \
                else rel < -threshold
            row["status"] = ("regressed" if worse
                             else "improved" if better else "ok")
        rows.append(row)
    new_only = sorted(set(new_m) - set(old_m))
    return rows, new_only


def regressions(rows: List[dict]) -> List[dict]:
    return [r for r in rows if r["status"] in ("regressed", "missing", "nan")]


def _fmt(v) -> str:
    return f"{v:.3f}" if isinstance(v, (int, float)) else "nan"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.compare", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("old", help="baseline BENCH_<suite>.json")
    ap.add_argument("new", help="candidate BENCH_<suite>.json")
    ap.add_argument("--threshold", type=float, default=THRESHOLD_DEFAULT,
                    metavar="FRAC",
                    help="relative noise threshold (default %(default)s)")
    ap.add_argument("--all", action="store_true",
                    help="print every metric, not just changes")
    args = ap.parse_args(argv)

    old, new = _load(args.old), _load(args.new)
    rows, new_only = compare_metrics(old, new, threshold=args.threshold)

    shown = rows if args.all else [r for r in rows if r["status"] != "ok"]
    if shown:
        print(f"{'status':10s} {'metric':40s} {'old':>12s} {'new':>12s} "
              f"{'delta':>8s}")
        for r in shown:
            rel = r["rel_delta"]
            delta = f"{rel:+.1%}" if isinstance(rel, float) \
                and math.isfinite(rel) else "-"
            print(f"{r['status']:10s} {r['name']:40s} "
                  f"{_fmt(r['old']):>12s} {_fmt(r['new']):>12s} {delta:>8s}")
    if new_only:
        print(f"\n{len(new_only)} new metric(s) not in baseline: "
              + ", ".join(new_only))

    bad = regressions(rows)
    n_improved = sum(1 for r in rows if r["status"] == "improved")
    print(f"\n{len(rows)} metrics compared: {len(bad)} regressed, "
          f"{n_improved} improved "
          f"(threshold {args.threshold:.0%}, suites "
          f"{old['suite']!r} -> {new['suite']!r})")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
