"""Beyond-figure: multi-tenant Zipf workload (paper §1 motivation via
Shahrad et al. [22] — most functions are rarely invoked) on a 36-core
worker.  Shows (a) the centralized scheduler hosts every function with one
polling core while per-instance polling caps the fleet, and (b) cold-tier
functions pay no polling tax."""
from __future__ import annotations

from repro.core.multitenant import run_zipf_workload
from repro.core.scheduler import PollingModel


def run(verbose=True):
    cen = run_zipf_workload("junctiond", n_functions=64, total_rps=1500,
                            duration_s=0.8)
    per = run_zipf_workload("junctiond", n_functions=64, total_rps=1500,
                            duration_s=0.8, polling=PollingModel.PER_INSTANCE)
    base = run_zipf_workload("containerd", n_functions=64, total_rps=1500,
                             duration_s=0.8)
    if verbose:
        print("# 64 functions, Zipf(1.5) popularity, 1500 rps total, 36-core worker")
        print(f"  {'config':28s} {'hosted':>6} {'work-cores':>10} "
              f"{'median_ms':>9} {'p99_ms':>8} {'cold-tier med':>13}")
        for name, r in (("junctiond centralized", cen),
                        ("junctiond per-instance(DPDK)", per),
                        ("containerd", base)):
            print(f"  {name:28s} {r.hosted:6d} {r.cores_for_work:10d} "
                  f"{r.overall.median_ms:9.2f} {r.overall.p99_ms:8.2f} "
                  f"{r.cold_tier.median_ms:13.2f}")
    rows = [
        ("multitenant_centralized_hosted", cen.hosted, "of 64 functions"),
        ("multitenant_per_instance_hosted", per.hosted, "of 64 (DPDK-style)"),
        ("multitenant_centralized_median", cen.overall.median_ms * 1e3, "us"),
        ("multitenant_containerd_median", base.overall.median_ms * 1e3, "us"),
        ("multitenant_cold_tier_median", cen.cold_tier.median_ms * 1e3,
         "us (rarely-invoked fns, junctiond)"),
    ]
    return rows, {}


if __name__ == "__main__":
    run()
