"""Beyond-figure: multi-tenant Zipf workload (paper §1 motivation via
Shahrad et al. [22] — most functions are rarely invoked) on a 36-core
worker.

The latency view is the ``multi-tenant-mix`` scenario (32-function Zipf
mix through the experiment runner); the capacity view — how many functions
a worker can host at all under each polling model — stays a direct
``run_zipf_workload`` measurement because it is about deploy-time core
reservations, not traffic."""
from __future__ import annotations

from repro.core.multitenant import run_zipf_workload
from repro.core.scheduler import PollingModel
from repro.experiments import ExperimentRunner, get_scenario


def run(verbose=True):
    doc = ExperimentRunner().run_suite([get_scenario("multi-tenant-mix")],
                                       suite="multitenant")
    if doc["failures"]:
        raise RuntimeError(doc["failures"][0]["error"])
    entry = doc["scenarios"][0]
    cen_mix = entry["backends"]["junctiond"]
    base_mix = entry["backends"]["containerd"]
    # capacity: per-instance (DPDK-style) polling vs centralized
    cen = run_zipf_workload("junctiond", n_functions=64, total_rps=1500,
                            duration_s=0.8)
    per = run_zipf_workload("junctiond", n_functions=64, total_rps=1500,
                            duration_s=0.8, polling=PollingModel.PER_INSTANCE)
    if verbose:
        print("# 32-function Zipf(1.5) mix, open loop, 36-core worker")
        for name, res in (("junctiond", cen_mix), ("containerd", base_mix)):
            print(f"  {name:10s} knee={res['knee_rps']:6.0f} rps "
                  f"median={res['median_ms']:7.2f}ms p99={res['p99_ms']:8.2f}ms")
        print("# capacity under each polling model (64 functions offered)")
        print(f"  centralized        : hosts {cen.hosted:2d}, "
              f"{cen.cores_for_work} cores left for work")
        print(f"  per-instance (DPDK): hosts {per.hosted:2d}, "
              f"{per.cores_for_work} cores left for work")
        print(f"  cold-tier median (rarely-invoked fns, junctiond): "
              f"{cen.cold_tier.median_ms:.2f} ms")
    rows = [
        ("multitenant_centralized_hosted", cen.hosted, "of 64 functions"),
        ("multitenant_per_instance_hosted", per.hosted, "of 64 (DPDK-style)"),
        ("multitenant_centralized_median", cen.overall.median_ms * 1e3, "us"),
        ("multitenant_containerd_median", base_mix["median_ms"] * 1e3,
         "us (32-fn mix)"),
        ("multitenant_mix_knee_junctiond", cen_mix["knee_rps"],
         "rps at p99<=10ms"),
        ("multitenant_cold_tier_median", cen.cold_tier.median_ms * 1e3,
         "us (rarely-invoked fns, junctiond)"),
    ]
    return rows, {"mix": entry, "capacity": {"centralized": cen.hosted,
                                             "per_instance": per.hosted}}


if __name__ == "__main__":
    run()
