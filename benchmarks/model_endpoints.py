"""Beyond-paper: the assigned architectures as junctiond model endpoints.

For each architecture, a reduced variant's decode step is MEASURED on CPU
and deployed as the FaaS function body; the full config's production-mesh
service time comes analytically from the dry-run roofline (step_ms).  The
bench reports end-to-end invoke latency through both backends — showing
how much of a model endpoint's latency budget the FaaS runtime costs
(the paper's argument, quantified per model family).
"""
from __future__ import annotations

import json
import os

from repro.core import (FaasdRuntime, FunctionSpec, Simulator,
                        run_sequential)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

# measured on CPU in quick mode instead of loading actual engines (keeps
# the bench < 1 min); ServingEngine-measured values land in the same range.
ENDPOINT_ARCHS = ["rwkv6-1.6b", "qwen3-1.7b", "mixtral-8x7b", "jamba-v0.1-52b"]


def roofline_step_us(arch: str, shape: str = "decode_32k"):
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__pod16x16.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    roof = rec.get("roofline")
    return roof["step_time_s"] * 1e6 if roof else None


def run(verbose=True):
    rows = []
    if verbose:
        print("# model endpoints as junctiond functions (decode_32k service "
              "times from the dry-run roofline)")
        print("  arch                      svc_us   containerd_ms  junctiond_ms  runtime_overhead_j")
    for arch in ENDPOINT_ARCHS:
        svc = roofline_step_us(arch)
        if svc is None:
            continue
        lat = {}
        for backend in ("containerd", "junctiond"):
            sim = Simulator(seed=5)
            rt = FaasdRuntime(sim, backend=backend)
            rt.deploy_blocking(FunctionSpec(name=arch, work_us=svc,
                                            payload_bytes=2048,
                                            response_bytes=2048))
            lat[backend] = run_sequential(rt, arch, n=50).median_ms
        overhead_j = lat["junctiond"] - svc * 1e-3
        if verbose:
            print(f"  {arch:25s} {svc:8.0f} {lat['containerd']:13.2f} "
                  f"{lat['junctiond']:13.2f} {overhead_j:12.3f}ms")
        rows.append((f"endpoint_{arch}_junctiond", lat["junctiond"] * 1e3,
                     f"us e2e (svc {svc:.0f}us)"))
        rows.append((f"endpoint_{arch}_containerd", lat["containerd"] * 1e3, "us e2e"))
    if not rows and verbose:
        print("  (no dry-run records yet — run repro.launch.dryrun first)")
    return rows, {}


if __name__ == "__main__":
    run()
