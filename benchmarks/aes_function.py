"""The paper's benchmark function on this host: AES-128-CTR over a 600-byte
input — measured for the XLA oracle and the Pallas kernel (interpret mode;
compiled-TPU timing is out of scope on CPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import aes_ctr

N_BLOCKS = 38   # ceil(600/16)


def _time(fn, *args, iters=50):
    fn(*args)  # warmup/compile
    # simlint: allow[wall-clock] microbenchmark times the real JAX kernel
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    # simlint: allow[wall-clock] microbenchmark times the real JAX kernel
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose=True):
    key_bytes = jnp.arange(16, dtype=jnp.int32)
    pt = jax.random.randint(jax.random.PRNGKey(0), (N_BLOCKS, 16), 0, 256)

    jit_ref = jax.jit(lambda p: ref.aes_ctr_ref(p, key_bytes))
    us_xla = _time(jit_ref, pt)
    us_interp = _time(lambda p: aes_ctr(p, key_bytes, backend="pallas_interpret"),
                      pt, iters=3)
    if verbose:
        print("# AES-128-CTR(600B) — the deployed FaaS function body")
        print(f"  XLA jit (CPU)          : {us_xla:9.1f} us/call")
        print(f"  Pallas interpret (CPU) : {us_interp:9.1f} us/call "
              "(correctness mode; TPU is the target)")
    return [("aes600b_xla_cpu", us_xla, "us/call"),
            ("aes600b_pallas_interpret", us_interp, "us/call")], {}


if __name__ == "__main__":
    run()
